//! Execution backends: the [`Backend`] trait and its two implementations.
//!
//! The trainer, experiment harness and benches all talk to a [`LoadedModel`]
//! — (`train_step` / `eval_step` / `features`) over host [`Tensor`]s — and
//! never care how the step is executed:
//!
//! * [`native`] (default): a pure-Rust CPU backend that implements the MoE
//!   forward/backward path (token embedding → top-k / expert-choice routing
//!   → grouped expert MLP → loss + aux load-balance loss) directly on
//!   `tensor::Tensor`, with an in-tree Adam optimizer. Needs **zero**
//!   Python/XLA artifacts: model signatures come from the built-in zoo
//!   (`manifest::zoo`).
//! * `pjrt` (cargo feature `pjrt`, off by default): loads AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them through
//!   PJRT. Tensors convert to device literals at this boundary only.
//!
//! State (`params` / `opt_state`) lives host-side as `Vec<Tensor>` in
//! manifest signature order and is threaded through the step loop by the
//! trainer.

pub mod ep;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::linalg::gemm::GemmKernels;
use crate::manifest::{Manifest, ModelEntry, MoeSpec, TensorSpec};
use crate::tensor::Tensor;

/// Scalar training metrics of one step/eval, keyed by manifest metric names.
pub type Metrics = BTreeMap<String, f64>;

/// Result of one executed train step: updated state tensors + metrics.
pub struct StepOutput {
    pub params: Vec<Tensor>,
    pub opt_state: Vec<Tensor>,
    pub metrics: Metrics,
}

/// Result of one forward-only inference call ([`Executable::infer`]).
#[derive(Debug, Clone, PartialEq)]
pub struct InferOutput {
    /// Predicted ids: `[b, dec_len]` i32 for LM entries (argmax token per
    /// decoder position), `[b]` i32 for vision entries (argmax class).
    pub predictions: Tensor,
    /// Per-example mean natural-log probability of the predicted ids — a
    /// serving-side confidence score, one entry per batch row.
    pub scores: Vec<f32>,
}

/// Where the grouped expert MLP of a MoE block executes.
///
/// The native backend splits every sparse block into router → dispatch →
/// **expert MLP** → combine; this trait owns the expert-MLP leg. The
/// default (`runtime::native`'s local exchange) runs all experts in
/// process; the expert-parallel exchange (`runtime::ep::EpRankExchange`)
/// routes each expert's token buffers to the rank that owns that expert's
/// weight shard, computes there, and routes the outputs back — real
/// all-to-all dispatch/combine over `parallel::collectives::EpGroup`.
///
/// Contract (what keeps N-rank execution bitwise-identical to local):
/// * `forward` consumes per-expert gathered inputs `xg[x]` (`[a_x, d]`
///   rows in assignment order) and returns per-expert raw outputs `y[x]`
///   (`[a_x, d]`, same row order). Forward is row-independent, so *where*
///   an expert's rows are computed can never change their values.
/// * `backward` consumes per-expert output grads `dye[x]` (`[a_x, d]`) and
///   returns per-expert input grads `dxg[x]`; expert weight grads are
///   accumulated into the full-size `dwi` (`[E·d·ff]`) / `dwo`
///   (`[E·ff·d]`) buffers. A sharded exchange writes only the slices of
///   the experts the rank owns, accumulating per-source partials in
///   ascending source order (the `reduce_sum_ordered` discipline).
/// * `bind` hands the exchange the executing backend's GEMM kernel family
///   before the step, so sharded expert compute runs on exactly the same
///   kernels as local compute.
///
/// Exchanges are stateful across one forward/backward pair: `forward` with
/// `want_cache` retains whatever `backward` needs (inputs and pre-ReLU
/// activations stay *at the rank that computed them* — they never cross
/// the interconnect twice).
pub trait ExpertExchange {
    fn bind(&mut self, gemm: GemmKernels) -> Result<()>;

    fn forward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        xg: Vec<Vec<f32>>,
        want_cache: bool,
    ) -> Result<Vec<Vec<f32>>>;

    fn backward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dye: Vec<Vec<f32>>,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<Vec<Vec<f32>>>;
}

/// One model's executable surface, produced by a [`Backend`].
///
/// `params` / `opt_state` follow the manifest signature order of the entry
/// the executable was loaded for; `batch` follows the manifest batch
/// signature; scalars are (lr, wd, step).
///
/// `Send + Sync` is part of the contract: the data-parallel trainer shares
/// one executable across replica worker threads (`grads` is `&self`).
pub trait Executable: Send + Sync {
    /// Which artifact kinds ("train" | "eval" | "features") can execute.
    fn has(&self, kind: &str) -> bool;

    /// One optimizer step: consumes the state and returns it updated.
    fn train_step(
        &self,
        params: Vec<Tensor>,
        opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput>;

    /// Evaluate one batch (no state update).
    fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics>;

    /// Frozen-feature extraction (vision): images [B,H,W,C] → [B, d].
    fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor>;

    /// Raw loss gradients for one batch, in manifest param order. Optional:
    /// backends that cannot expose gradients (PJRT fuses them into the
    /// update) return an error. Used by gradient-check tests.
    fn grads(&self, _params: &[Tensor], _batch: &[Tensor]) -> Result<(Metrics, Vec<Tensor>)> {
        bail!("this backend does not expose raw gradients")
    }

    /// [`Executable::grads`] with the expert MLP legs of every MoE block
    /// executed by `exchange` instead of locally — the expert-parallel
    /// entry point (`coordinator::trainer::mesh_train_step`). Optional:
    /// backends without a splittable step return an error.
    fn grads_ep(
        &self,
        _params: &[Tensor],
        _batch: &[Tensor],
        _exchange: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Vec<Tensor>)> {
        bail!("this backend does not support expert-parallel execution")
    }

    /// Forward-only inference: `inputs` follows the manifest's inference
    /// signature ([`ModelEntry::infer_batch`] — no targets/labels/masks)
    /// with any leading batch dim, and no backward or optimizer buffers
    /// are ever allocated. The serving path (`serve::Engine`). Optional:
    /// backends without a forward-only entry return an error.
    fn infer(&self, _params: &[Tensor], _inputs: &[Tensor]) -> Result<InferOutput> {
        bail!("this backend does not support forward-only inference")
    }

    /// [`Executable::infer`] with the expert MLP legs of every MoE block
    /// executed by `exchange` — EP-sharded serving on a mesh
    /// (`serve::mesh_infer`). Optional, like [`Executable::grads_ep`].
    fn infer_ep(
        &self,
        _params: &[Tensor],
        _inputs: &[Tensor],
        _exchange: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        bail!("this backend does not support expert-parallel inference")
    }
}

/// An execution backend: turns a manifest entry into an [`Executable`].
pub trait Backend {
    fn platform(&self) -> String;

    /// Load one model. `kinds` selects which executables to build ("train",
    /// "eval", "features") — backends with a compile cost (PJRT) only build
    /// what an experiment needs; the native backend ignores it (its
    /// "compilation" is free).
    fn load_model(&self, manifest: &Manifest, name: &str, kinds: &[&str]) -> Result<LoadedModel>;
}

/// A loaded model: the manifest entry plus a backend executable.
pub struct LoadedModel {
    pub entry: ModelEntry,
    exec: Box<dyn Executable>,
}

impl LoadedModel {
    pub fn new(entry: ModelEntry, exec: Box<dyn Executable>) -> LoadedModel {
        LoadedModel { entry, exec }
    }

    /// Which artifact kinds have executables.
    pub fn has(&self, kind: &str) -> bool {
        self.exec.has(kind)
    }

    /// Execute one training step.
    ///
    /// `params` / `opt_state` are consumed in manifest order and returned
    /// updated (so callers thread them through a loop).
    pub fn train_step(
        &self,
        params: Vec<Tensor>,
        opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput> {
        let e = &self.entry;
        if params.len() != e.params.len()
            || opt_state.len() != e.opt_state.len()
            || batch.len() != e.batch.len()
        {
            bail!(
                "signature mismatch: got {}/{}/{} params/opt/batch, want {}/{}/{}",
                params.len(),
                opt_state.len(),
                batch.len(),
                e.params.len(),
                e.opt_state.len(),
                e.batch.len()
            );
        }
        self.exec.train_step(params, opt_state, batch, lr, wd, step)
    }

    /// Evaluate one batch (no state update).
    pub fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics> {
        self.exec.eval_step(params, batch)
    }

    /// Frozen-feature extraction (vit only): images [B,H,W,C] → [B, d].
    pub fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor> {
        self.exec.features(params, images)
    }

    /// Raw loss gradients (native backend only); see [`Executable::grads`].
    pub fn grads(&self, params: &[Tensor], batch: &[Tensor]) -> Result<(Metrics, Vec<Tensor>)> {
        self.exec.grads(params, batch)
    }

    /// Raw loss gradients with the expert MLP executed through `exchange`
    /// (expert parallelism); see [`Executable::grads_ep`].
    pub fn grads_ep(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Vec<Tensor>)> {
        self.exec.grads_ep(params, batch, exchange)
    }

    /// Arity/dtype gate shared by the two inference entry points: `inputs`
    /// must match the entry's inference signature tensor-for-tensor in
    /// everything but the leading (batch) dim.
    fn check_infer_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        let specs = self.entry.infer_batch();
        if inputs.len() != specs.len() {
            bail!(
                "inference on `{}` takes {} input tensor(s) ({}), got {}",
                self.entry.name,
                specs.len(),
                specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", "),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(specs) {
            if t.shape.len() != spec.shape.len()
                || t.shape[1..] != spec.shape[1..]
                || t.dtype() != spec.dtype
            {
                bail!(
                    "inference input `{}` must be {:?} {:?} with any batch dim, got {:?} {:?}",
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape
                );
            }
        }
        Ok(())
    }

    /// Forward-only inference on `inputs` (the manifest inference
    /// signature, any batch dim); see [`Executable::infer`].
    pub fn infer(&self, params: &[Tensor], inputs: &[Tensor]) -> Result<InferOutput> {
        self.check_infer_inputs(inputs)?;
        self.exec.infer(params, inputs)
    }

    /// Forward-only inference with the expert MLP executed through
    /// `exchange` (EP-sharded serving); see [`Executable::infer_ep`].
    pub fn infer_ep(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        self.check_infer_inputs(inputs)?;
        self.exec.infer_ep(params, inputs, exchange)
    }
}

/// Backend selector + the façade the rest of the crate uses.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Default runtime: the native pure-Rust CPU backend.
    pub fn new() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(native::NativeBackend::new()) })
    }

    /// PJRT runtime over AOT HLO artifacts (requires the `pjrt` feature and
    /// a real xla crate in place of the vendored stub).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(pjrt::PjrtBackend::new()?) })
    }

    /// The backend that can actually execute `manifest`: AOT manifests
    /// (loaded from `artifacts/`) run on PJRT, the native zoo on the native
    /// backend. `Manifest::load_or_native` only returns an AOT manifest
    /// when the `pjrt` feature is compiled in, so the pairing is total.
    pub fn for_manifest(manifest: &Manifest) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            if manifest.source_hash != crate::manifest::zoo::NATIVE_SOURCE {
                return Runtime::pjrt();
            }
        }
        let _ = manifest;
        Runtime::new()
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn load_model(
        &self,
        manifest: &Manifest,
        name: &str,
        kinds: &[&str],
    ) -> Result<LoadedModel> {
        self.backend.load_model(manifest, name, kinds)
    }
}

// ---------------------------------------------------------------------------
// Optimizer
// ---------------------------------------------------------------------------

const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// One Adam step with decoupled weight decay, shared by the native
/// backend's fused `train_step` and the data-parallel trainer's single
/// post-all-reduce update.
///
/// State layout contract: `opt_state` holds two slots per parameter in
/// manifest order — `(m, v)` at indices `(2i, 2i+1)` — and `grads[i]`
/// matches `params[i]` element count. `step` drives bias correction and is
/// the *global* step (1-based; 0 is clamped to 1).
pub fn adam_update(
    params: &mut [Tensor],
    opt_state: &mut [Tensor],
    grads: &[Vec<f32>],
    lr: f64,
    wd: f64,
    step: u64,
) -> Result<()> {
    if grads.len() != params.len() || opt_state.len() != 2 * params.len() {
        bail!(
            "adam_update: {} params need {} grads and {} optimizer slots (got {}, {})",
            params.len(),
            params.len(),
            2 * params.len(),
            grads.len(),
            opt_state.len()
        );
    }
    let t = step.max(1) as f64;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    let (b1, b2) = (ADAM_B1 as f32, ADAM_B2 as f32);
    let lr32 = lr as f32;
    let wd32 = wd as f32;
    let (bc1f, bc2f) = (bc1 as f32, bc2 as f32);
    for i in 0..params.len() {
        let g = &grads[i];
        // m and v are adjacent slots; split so both borrow mutably at once
        // (no per-step accumulator copies on the hot path).
        let (head, tail) = opt_state.split_at_mut(2 * i + 1);
        let m = head[2 * i].f32s_mut()?;
        let vs = tail[0].f32s_mut()?;
        let p = params[i].f32s_mut()?;
        if g.len() != p.len() {
            bail!("adam_update: grad {} has {} elements, param has {}", i, g.len(), p.len());
        }
        for j in 0..p.len() {
            let gj = g[j];
            m[j] = b1 * m[j] + (1.0 - b1) * gj;
            vs[j] = b2 * vs[j] + (1.0 - b2) * gj * gj;
            let mhat = m[j] / bc1f;
            let vhat = vs[j] / bc2f;
            p[j] -= lr32 * (mhat / (vhat.sqrt() + ADAM_EPS) + wd32 * p[j]);
        }
    }
    Ok(())
}

/// Bind a checkpoint's tensors (in manifest order) to a state vector.
/// Delegates to the one spec-binding implementation
/// (`checkpoint::bind_tensors`).
pub fn tensors_from_checkpoint(
    ck: &crate::checkpoint::Checkpoint,
    specs: &[TensorSpec],
) -> Result<Vec<Tensor>> {
    crate::checkpoint::bind_tensors(ck, specs)
}

/// Convert state tensors back into a named checkpoint.
pub fn checkpoint_from_tensors(
    model: &str,
    step: u64,
    provenance: &str,
    specs: &[TensorSpec],
    tensors: &[Tensor],
) -> Result<crate::checkpoint::Checkpoint> {
    if specs.len() != tensors.len() {
        bail!("state has {} tensors but the signature lists {}", tensors.len(), specs.len());
    }
    let mut ck = crate::checkpoint::Checkpoint::new(model, step, provenance);
    for (s, t) in specs.iter().zip(tensors) {
        ck.insert(&s.name, t.clone());
    }
    Ok(ck)
}
