//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` for
//! why). Python never runs on this path: artifacts are compiled once at
//! `Runtime::load_model` and then executed step after step by the trainer.
//!
//! Output convention (probed at bring-up, DESIGN.md): the artifacts are
//! lowered with `return_tuple=True`, and this PJRT build returns the whole
//! result as a *single tuple buffer* regardless of arity. Each step we sync
//! the tuple to a host literal and decompose it; on the CPU client this is a
//! memcpy, and the decomposed parameter literals are fed straight back into
//! the next step without re-staging (see `rust/benches/runtime_step.rs`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::tensor::Tensor;

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct LoadedModel {
    pub entry: ModelEntry,
    train: Option<xla::PjRtLoadedExecutable>,
    eval: Option<xla::PjRtLoadedExecutable>,
    features: Option<xla::PjRtLoadedExecutable>,
}

/// Scalar training metrics of one step/eval, keyed by manifest metric names.
pub type Metrics = BTreeMap<String, f64>;

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?)
    }

    /// Load and compile the artifacts of one model. `kinds` selects which
    /// executables to build ("train", "eval", "features") — compiling only
    /// what an experiment needs keeps sweep startup fast (XLA compilation of
    /// a train-step module dominates experiment startup; see EXPERIMENTS.md
    /// §Perf).
    pub fn load_model(
        &self,
        manifest: &Manifest,
        name: &str,
        kinds: &[&str],
    ) -> Result<LoadedModel> {
        let entry = manifest.model(name)?.clone();
        let get = |k: &str| -> Result<Option<xla::PjRtLoadedExecutable>> {
            if !kinds.contains(&k) || !entry.artifacts.contains_key(k) {
                return Ok(None);
            }
            Ok(Some(self.compile(&manifest.artifact_path(&entry, k)?)?))
        };
        let train = get("train")?;
        let eval = get("eval")?;
        let features = get("features")?;
        Ok(LoadedModel { entry, train, eval, features })
    }
}

impl LoadedModel {
    /// Which artifact kinds have compiled executables.
    pub fn has(&self, kind: &str) -> bool {
        match kind {
            "train" => self.train.is_some(),
            "eval" => self.eval.is_some(),
            "features" => self.features.is_some(),
            _ => false,
        }
    }
}

/// Result of one executed train step: updated state literals + metrics.
pub struct StepOutput {
    pub params: Vec<xla::Literal>,
    pub opt_state: Vec<xla::Literal>,
    pub metrics: Metrics,
}

impl LoadedModel {
    /// Execute one training step.
    ///
    /// `params` / `opt_state` are consumed in manifest order and returned
    /// updated (so callers thread them through a loop); `batch` follows the
    /// manifest batch signature; scalars are (lr, wd, step).
    pub fn train_step(
        &self,
        params: Vec<xla::Literal>,
        opt_state: Vec<xla::Literal>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput> {
        let exe = self.train.as_ref().context("train executable not loaded")?;
        let e = &self.entry;
        if params.len() != e.params.len()
            || opt_state.len() != e.opt_state.len()
            || batch.len() != e.batch.len()
        {
            bail!(
                "signature mismatch: got {}/{}/{} params/opt/batch, want {}/{}/{}",
                params.len(), opt_state.len(), batch.len(),
                e.params.len(), e.opt_state.len(), e.batch.len()
            );
        }
        let mut inputs: Vec<xla::Literal> = params;
        inputs.extend(opt_state);
        for t in batch {
            inputs.push(t.to_literal()?);
        }
        inputs.push(Tensor::scalar_f32(lr as f32).to_literal()?);
        inputs.push(Tensor::scalar_f32(wd as f32).to_literal()?);
        inputs.push(Tensor::scalar_f32(step as f32).to_literal()?);

        let out = exe.execute::<xla::Literal>(&inputs)?;
        let mut flat = out[0][0].to_literal_sync()?.to_tuple()?;
        let expected = e.params.len() + e.opt_state.len() + e.metrics.len();
        if flat.len() != expected {
            bail!("train step returned {} outputs, expected {expected}", flat.len());
        }
        let metrics_lits = flat.split_off(e.params.len() + e.opt_state.len());
        let opt_lits = flat.split_off(e.params.len());
        let metrics = extract_metrics(&e.metrics, &metrics_lits)?;
        Ok(StepOutput { params: flat, opt_state: opt_lits, metrics })
    }

    /// Evaluate one batch (no state update).
    pub fn eval_step(&self, params: &[xla::Literal], batch: &[Tensor]) -> Result<Metrics> {
        let exe = self.eval.as_ref().context("eval executable not loaded")?;
        let e = &self.entry;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + batch.len());
        for p in params {
            // Literal has no cheap clone; round-trip through host tensor.
            inputs.push(Tensor::from_literal(p)?.to_literal()?);
        }
        for t in batch {
            inputs.push(t.to_literal()?);
        }
        let out = exe.execute::<xla::Literal>(&inputs)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple()?;
        extract_metrics(&e.metrics, &flat)
    }

    /// Frozen-feature extraction (vit only): images [B,H,W,C] → [B, d].
    pub fn features(&self, params: &[xla::Literal], images: &Tensor) -> Result<Tensor> {
        let exe = self.features.as_ref().context("features executable not loaded")?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 1);
        for p in params {
            inputs.push(Tensor::from_literal(p)?.to_literal()?);
        }
        inputs.push(images.to_literal()?);
        let out = exe.execute::<xla::Literal>(&inputs)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple()?;
        Tensor::from_literal(&flat[0])
    }
}

fn extract_metrics(names: &[String], lits: &[xla::Literal]) -> Result<Metrics> {
    let mut m = Metrics::new();
    for (name, lit) in names.iter().zip(lits) {
        let t = Tensor::from_literal(lit)?;
        m.insert(name.clone(), t.f32s()?[0] as f64);
    }
    Ok(m)
}

/// Convert a checkpoint's tensors (in manifest order) to input literals.
pub fn literals_from_checkpoint(
    ck: &crate::checkpoint::Checkpoint,
    specs: &[crate::manifest::TensorSpec],
) -> Result<Vec<xla::Literal>> {
    specs
        .iter()
        .map(|s| {
            let t = ck.get(&s.name)?;
            if t.shape != s.shape {
                bail!("tensor `{}` shape {:?} != manifest {:?}", s.name, t.shape, s.shape);
            }
            t.to_literal()
        })
        .collect()
}

/// Convert state literals back into a named checkpoint.
pub fn checkpoint_from_literals(
    model: &str,
    step: u64,
    provenance: &str,
    specs: &[crate::manifest::TensorSpec],
    lits: &[xla::Literal],
) -> Result<crate::checkpoint::Checkpoint> {
    let mut ck = crate::checkpoint::Checkpoint::new(model, step, provenance);
    for (s, l) in specs.iter().zip(lits) {
        ck.insert(&s.name, Tensor::from_literal(l)?);
    }
    Ok(ck)
}
