//! Execution backends: the [`Backend`] trait and its two implementations.
//!
//! The trainer, experiment harness and benches all talk to a [`LoadedModel`]
//! — (`train_step` / `eval_step` / `features`) over host [`Tensor`]s — and
//! never care how the step is executed:
//!
//! * [`native`] (default): a pure-Rust CPU backend that implements the MoE
//!   forward/backward path (token embedding → top-k / expert-choice routing
//!   → grouped expert MLP → loss + aux load-balance loss) directly on
//!   `tensor::Tensor`, with an in-tree Adam optimizer. Needs **zero**
//!   Python/XLA artifacts: model signatures come from the built-in zoo
//!   (`manifest::zoo`).
//! * `pjrt` (cargo feature `pjrt`, off by default): loads AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them through
//!   PJRT. Tensors convert to device literals at this boundary only.
//!
//! State (`params` / `opt_state`) lives host-side as `Vec<Tensor>` in
//! manifest signature order and is threaded through the step loop by the
//! trainer.

pub mod ep;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::checkpoint::quant::Precision;
use crate::linalg::gemm::GemmKernels;
use crate::manifest::{Manifest, ModelEntry, MoeSpec, TensorSpec};
use crate::tensor::Tensor;

/// Scalar training metrics of one step/eval, keyed by manifest metric names.
pub type Metrics = BTreeMap<String, f64>;

/// Result of one executed train step: updated state tensors + metrics.
pub struct StepOutput {
    pub params: Vec<Tensor>,
    pub opt_state: Vec<Tensor>,
    pub metrics: Metrics,
}

/// Result of one forward-only inference call ([`Executable::infer`]).
#[derive(Debug, Clone, PartialEq)]
pub struct InferOutput {
    /// Predicted ids: `[b, dec_len]` i32 for LM entries (argmax token per
    /// decoder position), `[b]` i32 for vision entries (argmax class).
    pub predictions: Tensor,
    /// Per-example mean natural-log probability of the predicted ids — a
    /// serving-side confidence score, one entry per batch row.
    pub scores: Vec<f32>,
}

/// Which traversal of a MoE block an exchange lifecycle call belongs to.
///
/// The same `plan → start_dispatch → finish_dispatch → start_combine →
/// finish_combine` lifecycle runs the forward and the backward leg of a
/// block; the leg picks what the owner computes in `finish_dispatch`
/// (expert MLP forward vs. masked hidden/input grads) and what
/// `finish_combine` returns (expert outputs vs. input grads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeLeg {
    /// The forward traversal; `want_cache` asks the owner to retain the
    /// gathered inputs and pre-ReLU activations for a later backward.
    Forward { want_cache: bool },
    /// The backward traversal (gated output grads out, input grads back).
    Backward,
}

impl ExchangeLeg {
    /// Wire name, part of every collective round tag (`{tag}/{wire}/mb{k}`).
    pub fn wire(&self) -> &'static str {
        match self {
            ExchangeLeg::Forward { .. } => "fwd",
            ExchangeLeg::Backward => "bwd",
        }
    }
}

/// Row ranges of the `m` microbatch chunks of an `rows`-row buffer: chunk
/// `k` covers rows `[k·rows/m, (k+1)·rows/m)`. Deterministic, balanced to
/// within one row, and order-preserving — concatenating the chunks back in
/// index order is the identity. Both exchange legs split with this same
/// function, so a backward chunk always aligns with the forward chunk
/// whose activations it consumes.
pub fn microbatch_ranges(rows: usize, m: usize) -> Vec<(usize, usize)> {
    let m = m.max(1);
    (0..m).map(|k| (k * rows / m, (k + 1) * rows / m)).collect()
}

/// Where (and how) the grouped expert MLP of a MoE block executes.
///
/// The native backend splits every sparse block into router → dispatch →
/// **expert MLP** → combine; this trait owns the expert-MLP leg. The
/// default (`runtime::native`'s local exchange) runs all experts in
/// process with every split-phase call completing immediately; the
/// expert-parallel exchange (`runtime::ep::EpRankExchange`) routes each
/// expert's token buffers to the rank that owns that expert's weight
/// shard, computes there, and routes the outputs back — real split-phase
/// all-to-all dispatch/combine over `parallel::collectives::EpGroup`.
///
/// **Lifecycle.** One block traversal is `plan` (validate + stage state),
/// then per microbatch `k`: `start_dispatch` (post chunk `k`'s all-to-all
/// without blocking) → `finish_dispatch` (complete the receive, run the
/// owner-side compute) → `start_combine` (post the results back) →
/// `finish_combine` (complete the return receive). The provided
/// [`ExpertExchange::forward`] / [`ExpertExchange::backward`] drivers run
/// this schedule double-buffered: microbatch `k+1`'s dispatch is posted
/// *before* microbatch `k` is computed, and the combine completions drain
/// only after every chunk's compute — so the all-to-all of one chunk
/// overlaps the expert compute of another, and the exposed `ep_alltoall`
/// wait shrinks to pipeline fill/drain (the bench's `overlap` section
/// measures exactly this window against the microbatch count).
///
/// Contract (what keeps overlapped N-rank execution bitwise-identical to
/// serial, for every microbatch count):
/// * Forward and the `dr`/`dxg` half of backward are row-independent
///   (`native::expert_mlp_forward`, `native::expert_mlp_backward_rows`),
///   so computing row chunks separately and concatenating in microbatch
///   order ([`microbatch_ranges`] preserves row order) is exact.
/// * The weight-grad GEMMs *reduce* over rows, so chunked partial sums
///   would change the float association. They are deferred instead:
///   `finish_weight_grads` runs once per block after the last microbatch,
///   on the concatenated full buffers, per `(expert, source)` in ascending
///   source order — exactly the fused path's GEMMs and the
///   `reduce_sum_ordered` discipline. A sharded exchange writes only the
///   `dwi` (`[E·d·ff]`) / `dwo` (`[E·ff·d]`) slices of experts it owns.
/// * `bind` hands the exchange the executing backend's GEMM kernel family
///   before the step, so sharded expert compute runs on exactly the same
///   kernels as local compute.
///
/// Exchanges are stateful across one forward/backward pair: a forward leg
/// with `want_cache` retains whatever backward needs (inputs and pre-ReLU
/// activations stay *at the rank that computed them* — they never cross
/// the interconnect twice). `reset` is the recoverable teardown: an
/// aborted step can strand staged state (caches whose backward never ran),
/// which `reset` drops; `has_pending` reports whether any such state is
/// staged (a cleanly-finished step leaves none).
pub trait ExpertExchange {
    fn bind(&mut self, gemm: GemmKernels) -> Result<()>;

    /// How many microbatches the pipeline drivers split each block's
    /// buffers into (>= 1; 1 = the fused schedule).
    fn microbatches(&self) -> usize {
        1
    }

    /// Token-vector width `d` of the buffers this exchange moves (the
    /// drivers need it to split rows).
    fn d_model(&self) -> usize;

    /// Validate the traversal and stage per-block state for `m` microbatch
    /// rounds of `leg` over block `tag`.
    fn plan(&mut self, tag: &str, spec: &MoeSpec, leg: ExchangeLeg, m: usize) -> Result<()>;

    /// Post microbatch `mb`'s dispatch all-to-all (per-expert row chunks,
    /// `chunk[x]` = `[rows_k, d]`) without blocking on peers.
    fn start_dispatch(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
        chunk: Vec<Vec<f32>>,
    ) -> Result<()>;

    /// Complete microbatch `mb`'s dispatch receive and run the owner-side
    /// compute for the received rows (expert MLP forward, or the
    /// row-independent backward half), staging the results for
    /// `start_combine`.
    fn finish_dispatch(&mut self, tag: &str, spec: &MoeSpec, leg: ExchangeLeg, mb: usize)
        -> Result<()>;

    /// Post microbatch `mb`'s combine all-to-all (results back to the
    /// token sources) without blocking on peers.
    fn start_combine(&mut self, tag: &str, spec: &MoeSpec, leg: ExchangeLeg, mb: usize)
        -> Result<()>;

    /// Complete microbatch `mb`'s combine receive: per-expert row chunks
    /// (`[rows_k, d]`, assignment order) — expert outputs on the forward
    /// leg, input grads on the backward leg.
    fn finish_combine(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// Fold the deferred weight-grad GEMMs of block `tag` into `dwi` /
    /// `dwo` (backward leg only; called once, after the last microbatch's
    /// `finish_dispatch`). Consumes the block's staged forward cache.
    fn finish_weight_grads(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<()>;

    /// Recoverable teardown: drop all staged per-block state (forward
    /// caches, in-flight chunks) left behind by an aborted step.
    fn reset(&mut self);

    /// Whether any staged state is pending (a cleanly-finished step leaves
    /// none; used by teardown assertions).
    fn has_pending(&self) -> bool;

    /// One forward traversal of block `tag`: per-expert gathered inputs
    /// `xg[x]` (`[a_x, d]`, assignment order) → per-expert raw outputs
    /// (same shape and row order). Provided: runs the double-buffered
    /// microbatch pipeline over the lifecycle methods.
    fn forward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        xg: Vec<Vec<f32>>,
        want_cache: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let leg = ExchangeLeg::Forward { want_cache };
        let out = drive_pipeline(self, tag, spec, leg, xg)?;
        Ok(out)
    }

    /// One backward traversal of block `tag`: per-expert gated output
    /// grads `dye[x]` → per-expert input grads, with the experts' weight
    /// grads folded into `dwi` / `dwo`. Provided: the same pipeline as
    /// `forward` plus the deferred weight-grad fold.
    fn backward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dye: Vec<Vec<f32>>,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<Vec<Vec<f32>>> {
        let leg = ExchangeLeg::Backward;
        let out = drive_pipeline_backward(self, tag, spec, leg, dye, dwi, dwo)?;
        Ok(out)
    }
}

/// Split per-expert buffers into `m` per-microbatch chunk sets. `m == 1`
/// is the fused fast path (no copies).
fn split_microbatches(bufs: Vec<Vec<f32>>, d: usize, m: usize) -> Vec<Vec<Vec<f32>>> {
    if m <= 1 {
        return vec![bufs];
    }
    let mut chunks: Vec<Vec<Vec<f32>>> = (0..m).map(|_| Vec::with_capacity(bufs.len())).collect();
    for data in &bufs {
        let rows = if d == 0 { 0 } else { data.len() / d };
        for (k, (lo, hi)) in microbatch_ranges(rows, m).into_iter().enumerate() {
            chunks[k].push(data[lo * d..hi * d].to_vec());
        }
    }
    chunks
}

/// Stitch per-microbatch, per-expert chunk results back into full
/// per-expert buffers (chunk concatenation in microbatch order).
fn stitch_microbatches(parts: Vec<Vec<Vec<f32>>>, e_cnt: usize) -> Result<Vec<Vec<f32>>> {
    let mut out: Vec<Vec<f32>> = (0..e_cnt).map(|_| Vec::new()).collect();
    for (k, part) in parts.into_iter().enumerate() {
        if part.len() != e_cnt {
            bail!("microbatch {k} returned {} expert buffers, want {e_cnt}", part.len());
        }
        for (x, mut c) in part.into_iter().enumerate() {
            out[x].append(&mut c);
        }
    }
    Ok(out)
}

/// The double-buffered schedule shared by both provided drivers: post
/// chunk `k+1`'s dispatch before computing chunk `k`, post each chunk's
/// combine as soon as it is computed, and only then drain the combine
/// completions — so a rank never blocks on a peer's compute between its
/// own chunks.
fn drive_pipeline<E: ExpertExchange + ?Sized>(
    ex: &mut E,
    tag: &str,
    spec: &MoeSpec,
    leg: ExchangeLeg,
    bufs: Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>> {
    let e_cnt = spec.num_experts;
    if bufs.len() != e_cnt {
        bail!("{} `{tag}`: {} expert buffers for {e_cnt} experts", leg.wire(), bufs.len());
    }
    let m = ex.microbatches().max(1);
    ex.plan(tag, spec, leg, m)?;
    let mut chunks = split_microbatches(bufs, ex.d_model(), m).into_iter();
    let first = chunks.next().expect("m >= 1 chunk");
    ex.start_dispatch(tag, spec, leg, 0, first)?;
    for k in 0..m {
        if let Some(next) = chunks.next() {
            ex.start_dispatch(tag, spec, leg, k + 1, next)?;
        }
        ex.finish_dispatch(tag, spec, leg, k)?;
        ex.start_combine(tag, spec, leg, k)?;
    }
    let mut parts = Vec::with_capacity(m);
    for k in 0..m {
        parts.push(ex.finish_combine(tag, spec, leg, k)?);
    }
    stitch_microbatches(parts, e_cnt)
}

/// [`drive_pipeline`] plus the backward-only deferred weight-grad fold,
/// run after every chunk's compute but before the combine drain (it is
/// rank-local, so it overlaps the peers' remaining compute).
fn drive_pipeline_backward<E: ExpertExchange + ?Sized>(
    ex: &mut E,
    tag: &str,
    spec: &MoeSpec,
    leg: ExchangeLeg,
    dye: Vec<Vec<f32>>,
    dwi: &mut [f32],
    dwo: &mut [f32],
) -> Result<Vec<Vec<f32>>> {
    let e_cnt = spec.num_experts;
    if dye.len() != e_cnt {
        bail!("{} `{tag}`: {} expert grad buffers for {e_cnt} experts", leg.wire(), dye.len());
    }
    let m = ex.microbatches().max(1);
    ex.plan(tag, spec, leg, m)?;
    let mut chunks = split_microbatches(dye, ex.d_model(), m).into_iter();
    let first = chunks.next().expect("m >= 1 chunk");
    ex.start_dispatch(tag, spec, leg, 0, first)?;
    for k in 0..m {
        if let Some(next) = chunks.next() {
            ex.start_dispatch(tag, spec, leg, k + 1, next)?;
        }
        ex.finish_dispatch(tag, spec, leg, k)?;
        ex.start_combine(tag, spec, leg, k)?;
    }
    ex.finish_weight_grads(tag, spec, dwi, dwo)?;
    let mut parts = Vec::with_capacity(m);
    for k in 0..m {
        parts.push(ex.finish_combine(tag, spec, leg, k)?);
    }
    stitch_microbatches(parts, e_cnt)
}

/// One model's executable surface, produced by a [`Backend`].
///
/// `params` / `opt_state` follow the manifest signature order of the entry
/// the executable was loaded for; `batch` follows the manifest batch
/// signature; scalars are (lr, wd, step).
///
/// `Send + Sync` is part of the contract: the data-parallel trainer shares
/// one executable across replica worker threads (`grads` is `&self`).
pub trait Executable: Send + Sync {
    /// Which artifact kinds ("train" | "eval" | "features") can execute.
    fn has(&self, kind: &str) -> bool;

    /// One optimizer step: consumes the state and returns it updated.
    fn train_step(
        &self,
        params: Vec<Tensor>,
        opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput>;

    /// Evaluate one batch (no state update).
    fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics>;

    /// Frozen-feature extraction (vision): images [B,H,W,C] → [B, d].
    fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor>;

    /// Raw loss gradients for one batch, in manifest param order. Optional:
    /// backends that cannot expose gradients (PJRT fuses them into the
    /// update) return an error. Used by gradient-check tests.
    fn grads(&self, _params: &[Tensor], _batch: &[Tensor]) -> Result<(Metrics, Vec<Tensor>)> {
        bail!("this backend does not expose raw gradients")
    }

    /// [`Executable::grads`] with the expert MLP legs of every MoE block
    /// executed by `exchange` instead of locally — the expert-parallel
    /// entry point (`coordinator::trainer::mesh_train_step`). Optional:
    /// backends without a splittable step return an error.
    fn grads_ep(
        &self,
        _params: &[Tensor],
        _batch: &[Tensor],
        _exchange: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Vec<Tensor>)> {
        bail!("this backend does not support expert-parallel execution")
    }

    /// Forward-only inference: `inputs` follows the manifest's inference
    /// signature ([`ModelEntry::infer_batch`] — no targets/labels/masks)
    /// with any leading batch dim, and no backward or optimizer buffers
    /// are ever allocated. The serving path (`serve::Engine`). Optional:
    /// backends without a forward-only entry return an error.
    fn infer(&self, _params: &[Tensor], _inputs: &[Tensor]) -> Result<InferOutput> {
        bail!("this backend does not support forward-only inference")
    }

    /// [`Executable::infer`] with the expert MLP legs of every MoE block
    /// executed by `exchange` — EP-sharded serving on a mesh
    /// (`serve::mesh_infer`). Optional, like [`Executable::grads_ep`].
    fn infer_ep(
        &self,
        _params: &[Tensor],
        _inputs: &[Tensor],
        _exchange: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        bail!("this backend does not support expert-parallel inference")
    }
}

/// An execution backend: turns a manifest entry into an [`Executable`].
pub trait Backend {
    fn platform(&self) -> String;

    /// Load one model. `kinds` selects which executables to build ("train",
    /// "eval", "features") — backends with a compile cost (PJRT) only build
    /// what an experiment needs; the native backend ignores it (its
    /// "compilation" is free).
    fn load_model(&self, manifest: &Manifest, name: &str, kinds: &[&str]) -> Result<LoadedModel>;
}

/// A loaded model: the manifest entry plus a backend executable.
pub struct LoadedModel {
    pub entry: ModelEntry,
    exec: Box<dyn Executable>,
}

impl LoadedModel {
    pub fn new(entry: ModelEntry, exec: Box<dyn Executable>) -> LoadedModel {
        LoadedModel { entry, exec }
    }

    /// Which artifact kinds have executables.
    pub fn has(&self, kind: &str) -> bool {
        self.exec.has(kind)
    }

    /// Execute one training step.
    ///
    /// `params` / `opt_state` are consumed in manifest order and returned
    /// updated (so callers thread them through a loop).
    pub fn train_step(
        &self,
        params: Vec<Tensor>,
        opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput> {
        let e = &self.entry;
        if params.len() != e.params.len()
            || opt_state.len() != e.opt_state.len()
            || batch.len() != e.batch.len()
        {
            bail!(
                "signature mismatch: got {}/{}/{} params/opt/batch, want {}/{}/{}",
                params.len(),
                opt_state.len(),
                batch.len(),
                e.params.len(),
                e.opt_state.len(),
                e.batch.len()
            );
        }
        self.exec.train_step(params, opt_state, batch, lr, wd, step)
    }

    /// Evaluate one batch (no state update).
    pub fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics> {
        self.exec.eval_step(params, batch)
    }

    /// Frozen-feature extraction (vit only): images [B,H,W,C] → [B, d].
    pub fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor> {
        self.exec.features(params, images)
    }

    /// Raw loss gradients (native backend only); see [`Executable::grads`].
    pub fn grads(&self, params: &[Tensor], batch: &[Tensor]) -> Result<(Metrics, Vec<Tensor>)> {
        self.exec.grads(params, batch)
    }

    /// Raw loss gradients with the expert MLP executed through `exchange`
    /// (expert parallelism); see [`Executable::grads_ep`].
    pub fn grads_ep(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Vec<Tensor>)> {
        self.exec.grads_ep(params, batch, exchange)
    }

    /// Arity/dtype gate shared by the two inference entry points: `inputs`
    /// must match the entry's inference signature tensor-for-tensor in
    /// everything but the leading (batch) dim.
    fn check_infer_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        let specs = self.entry.infer_batch();
        if inputs.len() != specs.len() {
            bail!(
                "inference on `{}` takes {} input tensor(s) ({}), got {}",
                self.entry.name,
                specs.len(),
                specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", "),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(specs) {
            if t.shape.len() != spec.shape.len()
                || t.shape[1..] != spec.shape[1..]
                || t.dtype() != spec.dtype
            {
                bail!(
                    "inference input `{}` must be {:?} {:?} with any batch dim, got {:?} {:?}",
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape
                );
            }
        }
        Ok(())
    }

    /// Forward-only inference on `inputs` (the manifest inference
    /// signature, any batch dim); see [`Executable::infer`].
    pub fn infer(&self, params: &[Tensor], inputs: &[Tensor]) -> Result<InferOutput> {
        self.check_infer_inputs(inputs)?;
        self.exec.infer(params, inputs)
    }

    /// Forward-only inference with the expert MLP executed through
    /// `exchange` (EP-sharded serving); see [`Executable::infer_ep`].
    pub fn infer_ep(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        self.check_infer_inputs(inputs)?;
        self.exec.infer_ep(params, inputs, exchange)
    }

    /// [`LoadedModel::infer`] at a serving [`Precision`]: non-f32
    /// precisions run on load-time-quantized weights
    /// (`checkpoint::quant::quantize_params`, applied per call — batch
    /// serving paths that reuse weights quantize once up front instead).
    /// `Precision::F32` is exactly [`LoadedModel::infer`].
    pub fn infer_prec(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        precision: Precision,
    ) -> Result<InferOutput> {
        if precision == Precision::F32 {
            return self.infer(params, inputs);
        }
        let q = crate::checkpoint::quant::quantize_params(&self.entry, params, precision)?;
        self.infer(&q, inputs)
    }

    /// [`LoadedModel::infer_ep`] at a serving [`Precision`]; see
    /// [`LoadedModel::infer_prec`]. `serve::mesh_infer` quantizes once
    /// before its rank fan-out rather than through this per-call wrapper.
    pub fn infer_ep_prec(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        exchange: &mut dyn ExpertExchange,
        precision: Precision,
    ) -> Result<InferOutput> {
        if precision == Precision::F32 {
            return self.infer_ep(params, inputs, exchange);
        }
        let q = crate::checkpoint::quant::quantize_params(&self.entry, params, precision)?;
        self.infer_ep(&q, inputs, exchange)
    }
}

/// Backend selector + the façade the rest of the crate uses.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Default runtime: the native pure-Rust CPU backend.
    pub fn new() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(native::NativeBackend::new()) })
    }

    /// Native backend on the vectorized inference kernels
    /// (`GemmKernels::Simd`): what `infer`/`serve --precision` load so the
    /// quantized path also runs the fast tier. Inference-only by
    /// convention — the trainers always construct [`Runtime::new`].
    pub fn native_simd() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(native::NativeBackend::simd_kernels()) })
    }

    /// Wrap an arbitrary backend. Tests use this to inject kind-respecting
    /// stubs (the native backend builds every kind for free, so cache
    /// recompile behavior is unobservable through it); production code
    /// uses the named constructors.
    pub fn from_backend(backend: Box<dyn Backend>) -> Runtime {
        Runtime { backend }
    }

    /// PJRT runtime over AOT HLO artifacts (requires the `pjrt` feature and
    /// a real xla crate in place of the vendored stub).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(pjrt::PjrtBackend::new()?) })
    }

    /// The backend that can actually execute `manifest`: AOT manifests
    /// (loaded from `artifacts/`) run on PJRT, the native zoo on the native
    /// backend. `Manifest::load_or_native` only returns an AOT manifest
    /// when the `pjrt` feature is compiled in, so the pairing is total.
    pub fn for_manifest(manifest: &Manifest) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            if manifest.source_hash != crate::manifest::zoo::NATIVE_SOURCE {
                return Runtime::pjrt();
            }
        }
        let _ = manifest;
        Runtime::new()
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn load_model(
        &self,
        manifest: &Manifest,
        name: &str,
        kinds: &[&str],
    ) -> Result<LoadedModel> {
        self.backend.load_model(manifest, name, kinds)
    }
}

// ---------------------------------------------------------------------------
// Optimizer
// ---------------------------------------------------------------------------

const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// One Adam step with decoupled weight decay, shared by the native
/// backend's fused `train_step` and the data-parallel trainer's single
/// post-all-reduce update.
///
/// State layout contract: `opt_state` holds two slots per parameter in
/// manifest order — `(m, v)` at indices `(2i, 2i+1)` — and `grads[i]`
/// matches `params[i]` element count. `step` drives bias correction and is
/// the *global* step (1-based; 0 is clamped to 1).
pub fn adam_update(
    params: &mut [Tensor],
    opt_state: &mut [Tensor],
    grads: &[Vec<f32>],
    lr: f64,
    wd: f64,
    step: u64,
) -> Result<()> {
    if grads.len() != params.len() || opt_state.len() != 2 * params.len() {
        bail!(
            "adam_update: {} params need {} grads and {} optimizer slots (got {}, {})",
            params.len(),
            params.len(),
            2 * params.len(),
            grads.len(),
            opt_state.len()
        );
    }
    let t = step.max(1) as f64;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    let (b1, b2) = (ADAM_B1 as f32, ADAM_B2 as f32);
    let lr32 = lr as f32;
    let wd32 = wd as f32;
    let (bc1f, bc2f) = (bc1 as f32, bc2 as f32);
    for i in 0..params.len() {
        let g = &grads[i];
        // m and v are adjacent slots; split so both borrow mutably at once
        // (no per-step accumulator copies on the hot path).
        let (head, tail) = opt_state.split_at_mut(2 * i + 1);
        let m = head[2 * i].f32s_mut()?;
        let vs = tail[0].f32s_mut()?;
        let p = params[i].f32s_mut()?;
        if g.len() != p.len() {
            bail!("adam_update: grad {} has {} elements, param has {}", i, g.len(), p.len());
        }
        for j in 0..p.len() {
            let gj = g[j];
            m[j] = b1 * m[j] + (1.0 - b1) * gj;
            vs[j] = b2 * vs[j] + (1.0 - b2) * gj * gj;
            let mhat = m[j] / bc1f;
            let vhat = vs[j] / bc2f;
            p[j] -= lr32 * (mhat / (vhat.sqrt() + ADAM_EPS) + wd32 * p[j]);
        }
    }
    Ok(())
}

/// Bind a checkpoint's tensors (in manifest order) to a state vector.
/// Delegates to the one spec-binding implementation
/// (`checkpoint::bind_tensors`).
pub fn tensors_from_checkpoint(
    ck: &crate::checkpoint::Checkpoint,
    specs: &[TensorSpec],
) -> Result<Vec<Tensor>> {
    crate::checkpoint::bind_tensors(ck, specs)
}

/// Convert state tensors back into a named checkpoint.
pub fn checkpoint_from_tensors(
    model: &str,
    step: u64,
    provenance: &str,
    specs: &[TensorSpec],
    tensors: &[Tensor],
) -> Result<crate::checkpoint::Checkpoint> {
    if specs.len() != tensors.len() {
        bail!("state has {} tensors but the signature lists {}", tensors.len(), specs.len());
    }
    let mut ck = crate::checkpoint::Checkpoint::new(model, step, provenance);
    for (s, t) in specs.iter().zip(tensors) {
        ck.insert(&s.name, t.clone());
    }
    Ok(ck)
}
