//! Native pure-Rust CPU backend: executes the MoE training path with zero
//! Python/XLA artifacts.
//!
//! The model family mirrors the signature contract of the AOT path at tiny
//! scale (see `manifest::zoo`): a T5-style encoder/decoder LM for span
//! corruption and a patch-embedding classifier for vision, where each block
//! is a residual feed-forward layer — dense (`mlp/wi`, `mlp/wo`) or sparse
//! (`moe/wi [E,d,f]`, `moe/wo [E,f,d]`, `moe/router [d,E]`). The sparse path
//! implements the paper's routing menu: Expert Choice and Top-1/Top-2
//! token-choice routing with capacity factors, routing groups, optional
//! batch-priority routing (BPR) and combine-weight renormalization, plus the
//! Switch-style auxiliary load-balance loss for token choice.
//!
//! **Capacity invariants.** Expert Choice keeps exactly
//! `c = max(1, ⌊n_g·C/E⌋)` tokens per expert per routing group (balanced by
//! construction); token choice caps each expert at `⌈n_g·C·k/E⌉` and drops
//! overflow, so `coverage <= 1` and the dispatched-token count never
//! exceeds `n_g·C·k` per group. Routing groups partition tokens in batch
//! order; group boundaries never straddle a data-parallel shard because
//! shards are themselves contiguous batch prefixes.
//!
//! **Compute path.** All matmuls run on the blocked, transposed-B kernels
//! in [`crate::linalg::gemm`] (shared by forward and backward); tower-level
//! products use the row-parallel `_big` variants while per-expert products
//! stay serial inside the expert-parallel `par_map` region — the two levels
//! never nest. The grouped expert MLP and the per-group Expert Choice
//! selection fan out across experts on scoped threads (rayon is unavailable
//! offline; `crate::util::par_map` is the in-tree substitute).
//!
//! **Split sparse step.** Every MoE block decomposes into router →
//! dispatch → expert MLP → combine, and the expert-MLP leg is pluggable
//! through [`crate::runtime::ExpertExchange`]: the default
//! `LocalExchange` runs all experts in process (exactly the fused PR 2
//! arithmetic), while `runtime::ep::EpRankExchange` ships each expert's
//! token buffers to the expert-parallel rank owning that expert's weight
//! shard and ships the outputs back (real all-to-all dispatch/combine).
//! [`expert_mlp_forward`] / [`expert_mlp_backward`] are the shared
//! per-expert kernels both exchanges call, so the sharded path can never
//! drift arithmetically from the local one.
//!
//! **Determinism.** Every result is a pure function of (params, batch,
//! scalars): thread counts only move work between workers, never reorder a
//! floating-point reduction (see the `gemm` and `par_map` contracts). This
//! is what makes data-parallel training bitwise-reproducible and lets the
//! surgery tests assert exact equality.
//!
//! Backward passes are hand-written (verified by finite differences in the
//! unit tests below) and the optimizer is Adam with decoupled weight decay
//! ([`crate::runtime::adam_update`], shared with the data-parallel
//! trainer); the optimizer state layout is two slots (`opt/<param>/m`,
//! `opt/<param>/v`) per parameter so the upcycling surgery can broadcast
//! dense accumulators across experts exactly as with the factored path.
//!
//! When the phase profiler (`util::bench::phases_enable`) is on, the step
//! is attributed to "router" / "dispatch" / "expert_mlp" / "combine" /
//! "backward" / "optimizer" buckets; `cargo bench --bench runtime_step`
//! turns that into the `BENCH_runtime.json` breakdown.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::gemm::GemmKernels;
use crate::manifest::{Manifest, ModelEntry, MoeSpec};
use crate::tensor::Tensor;
use crate::util::bench::phase;
use crate::util::par_map;

use super::{
    adam_update, Backend, ExchangeLeg, Executable, ExpertExchange, InferOutput, LoadedModel,
    Metrics, StepOutput,
};

/// Coefficient on the auxiliary load-balance loss (token-choice routers).
pub const AUX_COEF: f32 = 1e-2;

/// The native backend: stateless; every model is "compiled" instantly.
/// Carries the GEMM kernel family its executables will run on.
pub struct NativeBackend {
    gemm: GemmKernels,
}

impl NativeBackend {
    /// Default backend: blocked kernels.
    pub fn new() -> NativeBackend {
        NativeBackend { gemm: GemmKernels::Blocked }
    }

    /// Scalar-kernel backend reproducing the PR 1 execution exactly; kept so
    /// `cargo bench --bench runtime_step` can measure the blocked-kernel
    /// speedup end-to-end on every run.
    pub fn reference_kernels() -> NativeBackend {
        NativeBackend { gemm: GemmKernels::Reference }
    }

    /// Vectorized-kernel backend (`linalg::simd`): the inference tier the
    /// serving CLI loads for `--precision`. The kernel family propagates
    /// through `ExpertExchange::bind`, so EP-sharded expert compute runs
    /// on the same tier as local compute.
    pub fn simd_kernels() -> NativeBackend {
        NativeBackend { gemm: GemmKernels::Simd }
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        match self.gemm {
            GemmKernels::Blocked => "native-cpu".to_string(),
            GemmKernels::Reference => "native-cpu-reference".to_string(),
            GemmKernels::Simd => "native-cpu-simd".to_string(),
        }
    }

    fn load_model(&self, manifest: &Manifest, name: &str, _kinds: &[&str]) -> Result<LoadedModel> {
        let entry = manifest.model(name)?.clone();
        let exec = NativeExec::new(entry.clone(), self.gemm)?;
        Ok(LoadedModel::new(entry, Box::new(exec)))
    }
}

fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

/// Result of one routing round over `n` tokens.
pub struct Routing {
    /// Token indices assigned to each expert, in assignment order.
    pub expert_tok: Vec<Vec<usize>>,
    /// Fraction of dispatched assignments per expert (token choice; used by
    /// the auxiliary loss). Zeros for Expert Choice.
    pub f_frac: Vec<f32>,
    /// Auxiliary load-balance loss value (token choice; 0 for EC).
    pub aux: f64,
    /// Fraction of tokens kept by at least one expert.
    pub coverage: f64,
    pub token_choice: bool,
}

/// Route `n` tokens given router probabilities `probs` [n, E].
///
/// Expert Choice: each expert takes its top `c = max(1, n_g·C/E)` tokens per
/// routing group. Token choice (top-1/top-2): each token picks its top-k
/// experts, subject to a per-group capacity `ceil(n_g·C·k/E)`; with BPR,
/// tokens are processed in order of decreasing router confidence.
pub fn route_tokens(spec: &MoeSpec, probs: &[f32], n: usize) -> Routing {
    let e_cnt = spec.num_experts;
    debug_assert_eq!(probs.len(), n * e_cnt);
    let mut expert_tok: Vec<Vec<usize>> = vec![Vec::new(); e_cnt];
    let group = if spec.group_size == 0 || spec.group_size >= n { n } else { spec.group_size };
    let token_choice = spec.router_type != "ec";
    let k = match spec.router_type.as_str() {
        "top1" => 1,
        _ => 2, // "top2", "top2bpr" and any other token-choice variant
    };

    let mut start = 0;
    while start < n {
        let end = (start + group).min(n);
        let ng = end - start;
        if !token_choice {
            let c =
                ((((ng as f64) * spec.capacity_factor) / e_cnt as f64).max(1.0) as usize).min(ng);
            // Per-expert top-c selection is independent across experts; fan
            // the sorts (the EC routing hot loop) out over scoped threads.
            let picks: Vec<Vec<usize>> = par_map(e_cnt, |x| {
                let mut idx: Vec<usize> = (start..end).collect();
                idx.sort_by(|&a, &b| {
                    probs[b * e_cnt + x].total_cmp(&probs[a * e_cnt + x]).then(a.cmp(&b))
                });
                idx.truncate(c);
                idx
            });
            for (x, chosen) in picks.into_iter().enumerate() {
                expert_tok[x].extend(chosen);
            }
        } else {
            let cap = (((ng as f64) * spec.capacity_factor * k as f64) / e_cnt as f64)
                .ceil()
                .max(1.0) as usize;
            let mut order: Vec<usize> = (start..end).collect();
            if spec.bpr {
                let maxp = |t: usize| -> f32 {
                    let row = &probs[t * e_cnt..(t + 1) * e_cnt];
                    row.iter().fold(f32::MIN, |m, &v| m.max(v))
                };
                order.sort_by(|&a, &b| maxp(b).total_cmp(&maxp(a)).then(a.cmp(&b)));
            }
            let mut count = vec![0usize; e_cnt];
            for &t in &order {
                let row = &probs[t * e_cnt..(t + 1) * e_cnt];
                for &x in top_k_indices(row, k).iter() {
                    if count[x] < cap {
                        count[x] += 1;
                        expert_tok[x].push(t);
                    }
                }
            }
        }
        start = end;
    }

    // Coverage + dispatch fractions + auxiliary loss.
    let mut covered = vec![false; n];
    let mut total_assign = 0usize;
    for toks in &expert_tok {
        total_assign += toks.len();
        for &t in toks {
            covered[t] = true;
        }
    }
    let coverage = covered.iter().filter(|&&c| c).count() as f64 / n.max(1) as f64;
    let mut f_frac = vec![0f32; e_cnt];
    let mut aux = 0f64;
    if token_choice && total_assign > 0 {
        for (x, toks) in expert_tok.iter().enumerate() {
            f_frac[x] = toks.len() as f32 / total_assign as f32;
        }
        // aux = E · Σ_e f_e · m_e with m_e the mean router prob of expert e.
        for x in 0..e_cnt {
            let mut m = 0f64;
            for t in 0..n {
                m += probs[t * e_cnt + x] as f64;
            }
            m /= n.max(1) as f64;
            aux += f_frac[x] as f64 * m;
        }
        aux *= e_cnt as f64;
    }
    Routing { expert_tok, f_frac, aux, coverage, token_choice }
}

/// Indices of the k largest values of `row` (k ∈ {1, 2}), deterministic.
fn top_k_indices(row: &[f32], k: usize) -> Vec<usize> {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    if k == 1 || row.len() == 1 {
        return vec![best];
    }
    let mut second = usize::MAX;
    for (i, &v) in row.iter().enumerate() {
        if i == best {
            continue;
        }
        if second == usize::MAX || v > row[second] {
            second = i;
        }
    }
    vec![best, second]
}

fn softmax_rows(x: &mut [f32], n: usize, m: usize) {
    for i in 0..n {
        let row = &mut x[i * m..(i + 1) * m];
        let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
        let mut s = 0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            s += *v;
        }
        let inv = 1.0 / s.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

// ---------------------------------------------------------------------------
// Per-expert MLP kernels (shared by the local and expert-parallel exchanges)
// ---------------------------------------------------------------------------

/// One expert's MLP forward on its gathered token rows `xg` (`[a, d]`,
/// assignment order): returns `(u, y)` — pre-ReLU hidden `[a, ff]` and raw
/// output `[a, d]`.
///
/// Row-independent by construction: every output row is a function of its
/// input row and the weights only, so splitting `xg` into row blocks (as
/// the expert-parallel dispatch does per source rank) and concatenating
/// the results is bitwise-identical to one fused call.
pub fn expert_mlp_forward(
    gemm: GemmKernels,
    wi_e: &[f32],
    wo_e: &[f32],
    xg: &[f32],
    d: usize,
    ff: usize,
) -> (Vec<f32>, Vec<f32>) {
    let a = if d == 0 { 0 } else { xg.len() / d };
    let mut u = vec![0f32; a * ff];
    gemm.mm_nn(xg, wi_e, a, d, ff, &mut u);
    let mut r = u.clone();
    relu_inplace(&mut r);
    let mut y = vec![0f32; a * d];
    gemm.mm_nn(&r, wo_e, a, ff, d, &mut y);
    (u, y)
}

/// One expert's MLP backward: gathered inputs `xg` `[a, d]`, cached
/// pre-ReLU hidden `u` `[a, ff]`, gated output grads `dye` `[a, d]` →
/// `(dwi [d·ff], dwo [ff·d], dxg [a·d])`.
///
/// The weight grads reduce over the `a` rows of this call only — the
/// expert-parallel owner invokes this once per source rank and accumulates
/// the partials in ascending source order, which is bitwise-identical to
/// the per-shard gradients the serial baseline computes and then
/// `reduce_sum_ordered`s.
pub fn expert_mlp_backward(
    gemm: GemmKernels,
    wi_e: &[f32],
    wo_e: &[f32],
    xg: &[f32],
    u: &[f32],
    dye: &[f32],
    d: usize,
    ff: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (dr, dxg) = expert_mlp_backward_rows(gemm, wi_e, wo_e, u, dye, d, ff);
    let (dwi, dwo) = expert_mlp_weight_grads(gemm, xg, u, &dr, dye, d, ff);
    (dwi, dwo, dxg)
}

/// The row-independent half of [`expert_mlp_backward`]: masked hidden grads
/// `dr` `[a, ff]` and input grads `dxg` `[a, d]` from cached pre-ReLU
/// hidden `u` and output grads `dye`. Every output row depends on its input
/// row and the weights only, so the overlapped pipeline computes this per
/// microbatch chunk and concatenating the chunks is bitwise-identical to
/// one fused call.
pub fn expert_mlp_backward_rows(
    gemm: GemmKernels,
    wi_e: &[f32],
    wo_e: &[f32],
    u: &[f32],
    dye: &[f32],
    d: usize,
    ff: usize,
) -> (Vec<f32>, Vec<f32>) {
    let a = if d == 0 { 0 } else { dye.len() / d };
    let mut dr = vec![0f32; a * ff];
    gemm.mm_nt(dye, wo_e, a, d, ff, &mut dr);
    for j in 0..a * ff {
        if u[j] <= 0.0 {
            dr[j] = 0.0;
        }
    }
    let mut dxg = vec![0f32; a * d];
    gemm.mm_nt(&dr, wi_e, a, ff, d, &mut dxg);
    (dr, dxg)
}

/// The row-*reducing* half of [`expert_mlp_backward`]: weight grads
/// `(dwi [d·ff], dwo [ff·d])` from the full gathered inputs `xg`, pre-ReLU
/// hidden `u`, masked hidden grads `dr` and output grads `dye` of one
/// `(expert, source)` buffer. These GEMMs reduce over the `a` rows, so
/// their float association depends on the row count — the overlapped
/// pipeline therefore *defers* them: it concatenates the per-microbatch
/// chunks of each operand and runs this once per `(expert, source)` on the
/// full buffers, exactly the call the fused path makes.
pub fn expert_mlp_weight_grads(
    gemm: GemmKernels,
    xg: &[f32],
    u: &[f32],
    dr: &[f32],
    dye: &[f32],
    d: usize,
    ff: usize,
) -> (Vec<f32>, Vec<f32>) {
    let a = if d == 0 { 0 } else { dye.len() / d };
    let mut r = u.to_vec();
    relu_inplace(&mut r);
    let mut dwo = vec![0f32; ff * d];
    gemm.mm_tn(&r, dye, a, ff, d, &mut dwo);
    let mut dwi = vec![0f32; d * ff];
    gemm.mm_tn(xg, dr, a, d, ff, &mut dwi);
    (dwi, dwo)
}

/// Two distinct mutable elements of a slice (for the wi/wo grad buffers).
fn two_mut(v: &mut [Vec<f32>], i: usize, j: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
    debug_assert_ne!(i, j);
    if i < j {
        let (a, b) = v.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = v.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

/// Round key for the local exchange's immediate-completion mailboxes
/// (same shape as the EP collective round tags, for debuggability).
fn round_key(tag: &str, leg: ExchangeLeg, mb: usize) -> String {
    format!("{tag}/{}/mb{mb}", leg.wire())
}

/// The default [`ExpertExchange`]: every expert computes in process, fanned
/// out over scoped threads (`par_map`), weights read straight from the
/// replicated `params`. Split-phase calls complete immediately —
/// `start_dispatch` stages the chunk, `finish_dispatch` computes, the
/// combine legs hand the staged results back — and `plan` pins the fused
/// single-microbatch schedule, so this is exactly the fused PR 2
/// arithmetic: the overlapped expert-parallel exchange must stay
/// bitwise-identical to it.
struct LocalExchange<'a> {
    exec: &'a NativeExec,
    params: &'a [Tensor],
    /// Per-block forward cache: for each expert, (gathered inputs, pre-ReLU
    /// hidden). Retained until `finish_weight_grads` consumes it.
    cache: BTreeMap<String, Vec<(Vec<f32>, Vec<f32>)>>,
    /// Chunks staged by `start_dispatch`, keyed by round.
    inbox: BTreeMap<String, Vec<Vec<f32>>>,
    /// Results staged by `finish_dispatch` for the combine legs.
    outbox: BTreeMap<String, Vec<Vec<f32>>>,
    /// Deferred weight-grad operands per block: for each expert,
    /// (masked hidden grads `dr`, gated output grads `dye`).
    wgrads: BTreeMap<String, Vec<(Vec<f32>, Vec<f32>)>>,
}

impl<'a> LocalExchange<'a> {
    fn new(exec: &'a NativeExec, params: &'a [Tensor]) -> LocalExchange<'a> {
        LocalExchange {
            exec,
            params,
            cache: BTreeMap::new(),
            inbox: BTreeMap::new(),
            outbox: BTreeMap::new(),
            wgrads: BTreeMap::new(),
        }
    }
}

impl ExpertExchange for LocalExchange<'_> {
    fn bind(&mut self, _gemm: GemmKernels) -> Result<()> {
        Ok(()) // always runs on the owning executable's kernels
    }

    fn d_model(&self) -> usize {
        self.exec.entry.config.d_model
    }

    fn plan(&mut self, tag: &str, _spec: &MoeSpec, leg: ExchangeLeg, m: usize) -> Result<()> {
        if m != 1 {
            bail!("local exchange runs the fused schedule: {m} microbatches requested for `{tag}`");
        }
        if matches!(leg, ExchangeLeg::Forward { .. }) {
            self.cache.remove(tag);
        }
        Ok(())
    }

    fn start_dispatch(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
        chunk: Vec<Vec<f32>>,
    ) -> Result<()> {
        if chunk.len() != spec.num_experts {
            bail!(
                "{} `{tag}`: {} expert chunks for {} experts",
                leg.wire(),
                chunk.len(),
                spec.num_experts
            );
        }
        self.inbox.insert(round_key(tag, leg, mb), chunk);
        Ok(())
    }

    fn finish_dispatch(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<()> {
        let key = round_key(tag, leg, mb);
        let bufs = self
            .inbox
            .remove(&key)
            .with_context(|| format!("`{key}`: dispatch finished before it started"))?;
        let d = self.exec.entry.config.d_model;
        let ff = self.exec.entry.config.d_ff;
        let wi = self.exec.pslice(self.params, &format!("{tag}/moe/wi"))?;
        let wo = self.exec.pslice(self.params, &format!("{tag}/moe/wo"))?;
        let gemm = self.exec.gemm;
        match leg {
            ExchangeLeg::Forward { want_cache } => {
                let per_expert: Vec<(Vec<f32>, Vec<f32>)> = {
                    let _ph = phase("expert_mlp");
                    par_map(spec.num_experts, |x| {
                        let wi_e = &wi[x * d * ff..(x + 1) * d * ff];
                        let wo_e = &wo[x * ff * d..(x + 1) * ff * d];
                        expert_mlp_forward(gemm, wi_e, wo_e, &bufs[x], d, ff)
                    })
                };
                let mut us = Vec::with_capacity(per_expert.len());
                let mut ys = Vec::with_capacity(per_expert.len());
                for (u, y) in per_expert {
                    us.push(u);
                    ys.push(y);
                }
                if want_cache {
                    self.cache.insert(tag.to_string(), bufs.into_iter().zip(us).collect());
                }
                self.outbox.insert(key, ys);
            }
            ExchangeLeg::Backward => {
                let cache = self
                    .cache
                    .get(tag)
                    .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
                if cache.len() != spec.num_experts {
                    bail!(
                        "backward `{tag}`: cache has {} experts, spec says {}",
                        cache.len(),
                        spec.num_experts
                    );
                }
                // Row-independent half only; the row-reducing weight grads
                // wait for `finish_weight_grads` (same GEMM split as the
                // expert-parallel exchange, so both stay bitwise-fused).
                let per_expert: Vec<(Vec<f32>, Vec<f32>)> = par_map(spec.num_experts, |x| {
                    let wi_e = &wi[x * d * ff..(x + 1) * d * ff];
                    let wo_e = &wo[x * ff * d..(x + 1) * ff * d];
                    let (_, u) = &cache[x];
                    expert_mlp_backward_rows(gemm, wi_e, wo_e, u, &bufs[x], d, ff)
                });
                let mut drs = Vec::with_capacity(per_expert.len());
                let mut dxgs = Vec::with_capacity(per_expert.len());
                for (dr, dxg) in per_expert {
                    drs.push(dr);
                    dxgs.push(dxg);
                }
                self.wgrads.insert(tag.to_string(), drs.into_iter().zip(bufs).collect());
                self.outbox.insert(key, dxgs);
            }
        }
        Ok(())
    }

    fn start_combine(
        &mut self,
        _tag: &str,
        _spec: &MoeSpec,
        _leg: ExchangeLeg,
        _mb: usize,
    ) -> Result<()> {
        Ok(()) // nothing crosses an interconnect; results sit in the outbox
    }

    fn finish_combine(
        &mut self,
        tag: &str,
        _spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let key = round_key(tag, leg, mb);
        self.outbox
            .remove(&key)
            .with_context(|| format!("`{key}`: combine finished before its dispatch"))
    }

    fn finish_weight_grads(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<()> {
        let d = self.exec.entry.config.d_model;
        let ff = self.exec.entry.config.d_ff;
        let e_cnt = spec.num_experts;
        if dwi.len() != e_cnt * d * ff || dwo.len() != e_cnt * ff * d {
            bail!("backward `{tag}`: weight grad buffers do not match [E={e_cnt}, d={d}, ff={ff}]");
        }
        let cache = self
            .cache
            .remove(tag)
            .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
        let ops = self.wgrads.remove(tag).with_context(|| {
            format!("backward `{tag}`: weight grads before any dispatch finished")
        })?;
        if cache.len() != e_cnt || ops.len() != e_cnt {
            bail!("backward `{tag}`: staged {} experts, spec says {e_cnt}", ops.len());
        }
        let gemm = self.exec.gemm;
        let per_expert: Vec<(Vec<f32>, Vec<f32>)> = par_map(e_cnt, |x| {
            let (xg, u) = &cache[x];
            let (dr, dye) = &ops[x];
            expert_mlp_weight_grads(gemm, xg, u, dr, dye, d, ff)
        });
        for (x, (dwi_e, dwo_e)) in per_expert.into_iter().enumerate() {
            accumulate(&mut dwi[x * d * ff..(x + 1) * d * ff], &dwi_e);
            accumulate(&mut dwo[x * ff * d..(x + 1) * ff * d], &dwo_e);
        }
        Ok(())
    }

    fn reset(&mut self) {
        self.cache.clear();
        self.inbox.clear();
        self.outbox.clear();
        self.wgrads.clear();
    }

    fn has_pending(&self) -> bool {
        !self.cache.is_empty()
            || !self.inbox.is_empty()
            || !self.outbox.is_empty()
            || !self.wgrads.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Executable
// ---------------------------------------------------------------------------

/// One residual feed-forward block: dense MLP or MoE.
struct Block {
    /// Parameter-name prefix (`enc/block_01`); MoE blocks use it as the
    /// exchange tag (`manifest::ModelEntry::moe_block_tags` lists the same
    /// tags, which is how the expert-parallel weight scatter finds them).
    tag: String,
    wi: String,
    wo: String,
    router: Option<String>,
    moe: Option<MoeSpec>,
}

/// Per-MoE-block forward cache for the backward pass. The expert-MLP
/// internals (gathered inputs, pre-ReLU hidden) live in the
/// [`ExpertExchange`] that computed them, not here — under expert
/// parallelism they stay at the owning rank.
struct MoeCache {
    probs: Vec<f32>,                   // [n, E]
    expert_tok: Vec<Vec<usize>>,       // per expert: assigned tokens
    expert_gate: Vec<Vec<f32>>,        // per expert: combine weight per row
    expert_y: Vec<Vec<f32>>,           // per expert: raw expert output [a, d]
    tok_sel: Vec<Vec<(usize, usize)>>, // per token: (expert, row within expert)
    f_frac: Vec<f32>,
    aux: f64,
    coverage: f64,
    token_choice: bool,
}

/// Per-tower forward cache.
struct TowerRun {
    inputs: Vec<Vec<f32>>, // input stream to each block
    dense_u: Vec<Vec<f32>>,
    moe: Vec<Option<MoeCache>>,
    aux: f64,
    coverage_sum: f64,
    moe_blocks: usize,
}

pub struct NativeExec {
    entry: ModelEntry,
    pidx: BTreeMap<String, usize>,
    enc_blocks: Vec<Block>,
    dec_blocks: Vec<Block>,
    gemm: GemmKernels,
}

fn make_blocks(entry: &ModelEntry, tower: &str) -> Vec<Block> {
    let cfg = &entry.config;
    let (count, moe) = if tower == "enc" {
        (cfg.num_layers, cfg.enc_moe.as_ref())
    } else {
        (cfg.num_decoder_layers, cfg.dec_moe.as_ref())
    };
    (0..count)
        .map(|i| {
            let prefix = format!("{tower}/block_{i:02}");
            let is_moe = moe.map(|m| m.moe_layers.contains(&i)).unwrap_or(false);
            if is_moe {
                Block {
                    wi: format!("{prefix}/moe/wi"),
                    wo: format!("{prefix}/moe/wo"),
                    router: Some(format!("{prefix}/moe/router")),
                    moe: moe.cloned(),
                    tag: prefix,
                }
            } else {
                Block {
                    wi: format!("{prefix}/mlp/wi"),
                    wo: format!("{prefix}/mlp/wo"),
                    router: None,
                    moe: None,
                    tag: prefix,
                }
            }
        })
        .collect()
}

impl NativeExec {
    pub fn new(entry: ModelEntry, gemm: GemmKernels) -> Result<NativeExec> {
        if entry.family != "lm" && entry.family != "vit" {
            bail!("native backend: unknown model family `{}`", entry.family);
        }
        let pidx: BTreeMap<String, usize> =
            entry.params.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
        // Optimizer slots must pair 2:1 with params in order (m, v).
        if entry.opt_state.len() != 2 * entry.params.len() {
            bail!(
                "native backend: expected {} optimizer slots (m, v per param), manifest has {}",
                2 * entry.params.len(),
                entry.opt_state.len()
            );
        }
        for (i, p) in entry.params.iter().enumerate() {
            let m = &entry.opt_state[2 * i];
            let v = &entry.opt_state[2 * i + 1];
            if m.name != format!("opt/{}/m", p.name) || v.name != format!("opt/{}/v", p.name) {
                bail!(
                    "native backend: optimizer slot order mismatch at `{}` (got `{}`, `{}`)",
                    p.name,
                    m.name,
                    v.name
                );
            }
        }
        let enc_blocks = make_blocks(&entry, "enc");
        let dec_blocks = make_blocks(&entry, "dec");
        let exec = NativeExec { entry, pidx, enc_blocks, dec_blocks, gemm };
        // Every block parameter must exist in the signature.
        for b in exec.enc_blocks.iter().chain(exec.dec_blocks.iter()) {
            for name in [Some(&b.wi), Some(&b.wo), b.router.as_ref()].into_iter().flatten() {
                if !exec.pidx.contains_key(name) {
                    bail!("native backend: block parameter `{name}` missing from manifest");
                }
            }
        }
        Ok(exec)
    }

    fn idx(&self, name: &str) -> Result<usize> {
        self.pidx.get(name).copied().ok_or_else(|| anyhow!("no parameter `{name}`"))
    }

    fn pslice<'a>(&self, params: &'a [Tensor], name: &str) -> Result<&'a [f32]> {
        params[self.idx(name)?].f32s()
    }

    fn check_params(&self, params: &[Tensor]) -> Result<()> {
        if params.len() != self.entry.params.len() {
            bail!("expected {} params, got {}", self.entry.params.len(), params.len());
        }
        for (t, s) in params.iter().zip(&self.entry.params) {
            if t.shape != s.shape {
                bail!("param `{}` shape {:?} != manifest {:?}", s.name, t.shape, s.shape);
            }
        }
        Ok(())
    }

    // -- forward/backward towers ------------------------------------------

    /// Forward one tower in place. `want_cache` retains the per-block
    /// inputs and activations needed by `tower_backward`; eval/features
    /// calls pass `false` and skip those copies entirely. `ex` executes the
    /// expert-MLP leg of every MoE block (local or expert-parallel).
    fn tower_forward(
        &self,
        params: &[Tensor],
        blocks: &[Block],
        h: &mut [f32],
        n: usize,
        want_cache: bool,
        ex: &mut dyn ExpertExchange,
    ) -> Result<TowerRun> {
        let d = self.entry.config.d_model;
        let ff = self.entry.config.d_ff;
        let mut run = TowerRun {
            inputs: Vec::with_capacity(blocks.len()),
            dense_u: Vec::with_capacity(blocks.len()),
            moe: Vec::with_capacity(blocks.len()),
            aux: 0.0,
            coverage_sum: 0.0,
            moe_blocks: 0,
        };
        for blk in blocks {
            // Snapshot of the block input (pre-residual) for backward.
            let x = if want_cache { h.to_vec() } else { Vec::new() };
            match &blk.moe {
                None => {
                    let wi = self.pslice(params, &blk.wi)?;
                    let wo = self.pslice(params, &blk.wo)?;
                    let mut u = vec![0f32; n * ff];
                    self.gemm.mm_nn_big(h, wi, n, d, ff, &mut u);
                    let mut r = u.clone();
                    relu_inplace(&mut r);
                    let mut y = vec![0f32; n * d];
                    self.gemm.mm_nn_big(&r, wo, n, ff, d, &mut y);
                    for j in 0..n * d {
                        h[j] += y[j];
                    }
                    run.dense_u.push(if want_cache { u } else { Vec::new() });
                    run.moe.push(None);
                }
                Some(spec) => {
                    let (cache, y) = self.moe_forward(params, blk, spec, h, n, want_cache, ex)?;
                    for j in 0..n * d {
                        h[j] += y[j];
                    }
                    run.aux += cache.aux;
                    run.coverage_sum += cache.coverage;
                    run.moe_blocks += 1;
                    run.dense_u.push(Vec::new());
                    run.moe.push(if want_cache { Some(cache) } else { None });
                }
            }
            run.inputs.push(x);
        }
        Ok(run)
    }

    /// One MoE block forward, split into router → dispatch → expert MLP →
    /// combine. Router and dispatch always run locally on this rank's
    /// tokens; the expert-MLP leg goes through `ex`, which may ship the
    /// per-expert buffers to other expert-parallel ranks and back.
    #[allow(clippy::too_many_arguments)]
    fn moe_forward(
        &self,
        params: &[Tensor],
        blk: &Block,
        spec: &MoeSpec,
        x: &[f32],
        n: usize,
        want_cache: bool,
        ex: &mut dyn ExpertExchange,
    ) -> Result<(MoeCache, Vec<f32>)> {
        let d = self.entry.config.d_model;
        let e_cnt = spec.num_experts;
        let wr = self.pslice(params, blk.router.as_ref().expect("moe block has router"))?;

        // Router: logits → softmax → routing decisions.
        let mut probs = vec![0f32; n * e_cnt];
        let routing = {
            let _ph = phase("router");
            self.gemm.mm_nn(x, wr, n, d, e_cnt, &mut probs);
            softmax_rows(&mut probs, n, e_cnt);
            route_tokens(spec, &probs, n)
        };

        // Dispatch: token → (expert, row) view, combine weights, and the
        // per-expert input gather (rows in assignment order — the buffers
        // an expert-parallel exchange puts on the wire).
        let _ph = phase("dispatch");
        let mut tok_sel: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
        for (x_i, toks) in routing.expert_tok.iter().enumerate() {
            for (j, &t) in toks.iter().enumerate() {
                tok_sel[t].push((x_i, j));
            }
        }
        let mut expert_gate: Vec<Vec<f32>> =
            routing.expert_tok.iter().map(|toks| vec![0f32; toks.len()]).collect();
        for (t, sel) in tok_sel.iter().enumerate() {
            if sel.is_empty() {
                continue;
            }
            let denom = if spec.renormalize {
                sel.iter().map(|&(x_i, _)| probs[t * e_cnt + x_i]).sum::<f32>().max(1e-9)
            } else {
                1.0
            };
            for &(x_i, j) in sel {
                expert_gate[x_i][j] = probs[t * e_cnt + x_i] / denom;
            }
        }
        let xg: Vec<Vec<f32>> = par_map(e_cnt, |x_i| {
            let toks = &routing.expert_tok[x_i];
            let mut buf = vec![0f32; toks.len() * d];
            for (j, &t) in toks.iter().enumerate() {
                buf[j * d..(j + 1) * d].copy_from_slice(&x[t * d..(t + 1) * d]);
            }
            buf
        });
        drop(_ph);

        // Grouped expert MLP through the exchange (phases itself).
        let expert_y = ex.forward(&blk.tag, spec, xg, want_cache)?;
        if expert_y.len() != e_cnt {
            bail!("exchange returned {} expert outputs, want {e_cnt}", expert_y.len());
        }
        for (x_i, y) in expert_y.iter().enumerate() {
            if y.len() != routing.expert_tok[x_i].len() * d {
                bail!(
                    "exchange output for expert {x_i} has {} values, want {} rows x {d}",
                    y.len(),
                    routing.expert_tok[x_i].len()
                );
            }
        }

        // Combine: gate-weighted scatter back to token order.
        let _ph = phase("combine");
        let mut out = vec![0f32; n * d];
        for (x_i, y) in expert_y.iter().enumerate() {
            for (j, &t) in routing.expert_tok[x_i].iter().enumerate() {
                let g = expert_gate[x_i][j];
                for c in 0..d {
                    out[t * d + c] += g * y[j * d + c];
                }
            }
        }
        drop(_ph);

        let cache = MoeCache {
            probs,
            expert_tok: routing.expert_tok,
            expert_gate,
            expert_y,
            tok_sel,
            f_frac: routing.f_frac,
            aux: routing.aux,
            coverage: routing.coverage,
            token_choice: routing.token_choice,
        };
        Ok((cache, out))
    }

    /// Backward through a tower. `dh` enters as d(tower output) and leaves
    /// as d(tower input); weight grads accumulate into `grads`.
    #[allow(clippy::too_many_arguments)]
    fn tower_backward(
        &self,
        params: &[Tensor],
        blocks: &[Block],
        run: &TowerRun,
        dh: &mut [f32],
        n: usize,
        grads: &mut [Vec<f32>],
        ex: &mut dyn ExpertExchange,
    ) -> Result<()> {
        let d = self.entry.config.d_model;
        let ff = self.entry.config.d_ff;
        for (bi, blk) in blocks.iter().enumerate().rev() {
            let x = &run.inputs[bi];
            let mut dx = vec![0f32; n * d];
            match &blk.moe {
                None => {
                    let wi = self.pslice(params, &blk.wi)?;
                    let wo = self.pslice(params, &blk.wo)?;
                    let u = &run.dense_u[bi];
                    let mut r = u.clone();
                    relu_inplace(&mut r);
                    let mut dwo = vec![0f32; ff * d];
                    self.gemm.mm_tn_big(&r, dh, n, ff, d, &mut dwo);
                    let mut dr = vec![0f32; n * ff];
                    self.gemm.mm_nt_big(dh, wo, n, d, ff, &mut dr);
                    for j in 0..n * ff {
                        if u[j] <= 0.0 {
                            dr[j] = 0.0;
                        }
                    }
                    let mut dwi = vec![0f32; d * ff];
                    self.gemm.mm_tn_big(x, &dr, n, d, ff, &mut dwi);
                    self.gemm.mm_nt_big(&dr, wi, n, ff, d, &mut dx);
                    accumulate(&mut grads[self.idx(&blk.wi)?], &dwi);
                    accumulate(&mut grads[self.idx(&blk.wo)?], &dwo);
                }
                Some(spec) => {
                    let cache = run.moe[bi].as_ref().expect("moe cache present");
                    self.moe_backward(params, blk, spec, cache, x, dh, &mut dx, n, grads, ex)?;
                }
            }
            for j in 0..n * d {
                dh[j] += dx[j];
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn moe_backward(
        &self,
        params: &[Tensor],
        blk: &Block,
        spec: &MoeSpec,
        cache: &MoeCache,
        x: &[f32],
        dh: &[f32],
        dx: &mut [f32],
        n: usize,
        grads: &mut [Vec<f32>],
        ex: &mut dyn ExpertExchange,
    ) -> Result<()> {
        let d = self.entry.config.d_model;
        let e_cnt = spec.num_experts;
        let router_name = blk.router.as_ref().expect("moe block has router");
        let wr = self.pslice(params, router_name)?;

        // Gated output-grad rows per expert (assignment order) — the
        // buffers an expert-parallel exchange ships to the owners.
        let dye: Vec<Vec<f32>> = par_map(e_cnt, |x_i| {
            let toks = &cache.expert_tok[x_i];
            let gates = &cache.expert_gate[x_i];
            let mut buf = vec![0f32; toks.len() * d];
            for (j, &t) in toks.iter().enumerate() {
                let g = gates[j];
                for c in 0..d {
                    buf[j * d + c] = g * dh[t * d + c];
                }
            }
            buf
        });

        // Expert weight grads accumulate where the experts live; the input
        // grads come back to this rank's tokens.
        let wi_idx = self.idx(&blk.wi)?;
        let wo_idx = self.idx(&blk.wo)?;
        let dxg = {
            let (dwi_buf, dwo_buf) = two_mut(grads, wi_idx, wo_idx);
            ex.backward(&blk.tag, spec, dye, dwi_buf, dwo_buf)?
        };
        if dxg.len() != e_cnt {
            bail!("exchange returned {} expert input grads, want {e_cnt}", dxg.len());
        }
        for (x_i, dxg_e) in dxg.iter().enumerate() {
            let toks = &cache.expert_tok[x_i];
            if dxg_e.len() != toks.len() * d {
                bail!(
                    "exchange input grad for expert {x_i} has {} values, want {} rows x {d}",
                    dxg_e.len(),
                    toks.len()
                );
            }
            for (j, &t) in toks.iter().enumerate() {
                for c in 0..d {
                    dx[t * d + c] += dxg_e[j * d + c];
                }
            }
        }

        // Combine-weight grads → router probabilities → router logits.
        let mut dp = vec![0f32; n * e_cnt];
        for (t, sel) in cache.tok_sel.iter().enumerate() {
            if sel.is_empty() {
                continue;
            }
            // dg for each selected expert: ⟨expert output, upstream grad⟩.
            let mut dgs: Vec<f32> = Vec::with_capacity(sel.len());
            for &(x_i, j) in sel {
                let y = &cache.expert_y[x_i][j * d..(j + 1) * d];
                let mut s = 0f32;
                for c in 0..d {
                    s += y[c] * dh[t * d + c];
                }
                dgs.push(s);
            }
            if spec.renormalize {
                let s: f32 = sel
                    .iter()
                    .map(|&(x_i, _)| cache.probs[t * e_cnt + x_i])
                    .sum::<f32>()
                    .max(1e-9);
                let gsum: f32 = sel
                    .iter()
                    .zip(&dgs)
                    .map(|(&(x_i, j), &dg)| dg * cache.expert_gate[x_i][j])
                    .sum();
                for (&(x_i, _), &dg) in sel.iter().zip(&dgs) {
                    dp[t * e_cnt + x_i] += (dg - gsum) / s;
                }
            } else {
                for (&(x_i, _), &dg) in sel.iter().zip(&dgs) {
                    dp[t * e_cnt + x_i] += dg;
                }
            }
        }
        // Auxiliary load-balance loss (token choice): d aux / d P[t,e] =
        // AUX_COEF · E · f_e / n (dispatch fractions treated constant).
        if cache.token_choice {
            let scale = AUX_COEF * e_cnt as f32 / n.max(1) as f32;
            for t in 0..n {
                for x_i in 0..e_cnt {
                    dp[t * e_cnt + x_i] += scale * cache.f_frac[x_i];
                }
            }
        }
        // Softmax Jacobian rows.
        let mut dlogits = vec![0f32; n * e_cnt];
        for t in 0..n {
            let p = &cache.probs[t * e_cnt..(t + 1) * e_cnt];
            let dpr = &dp[t * e_cnt..(t + 1) * e_cnt];
            let dot: f32 = p.iter().zip(dpr).map(|(&a, &b)| a * b).sum();
            for x_i in 0..e_cnt {
                dlogits[t * e_cnt + x_i] = p[x_i] * (dpr[x_i] - dot);
            }
        }
        let mut dwr = vec![0f32; d * e_cnt];
        self.gemm.mm_tn(x, &dlogits, n, d, e_cnt, &mut dwr);
        accumulate(&mut grads[self.idx(router_name)?], &dwr);
        self.gemm.mm_nt(&dlogits, wr, n, e_cnt, d, dx);
        Ok(())
    }

    // -- language model ----------------------------------------------------

    fn lm_step(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        want_grads: bool,
        ex: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Option<Vec<Vec<f32>>>)> {
        let cfg = &self.entry.config;
        let (d, v) = (cfg.d_model, cfg.vocab_size);
        if batch.len() != 4 {
            bail!("lm batch must be [enc_tokens, dec_tokens, targets, loss_mask]");
        }
        let enc_tok = batch[0].i32s().context("enc_tokens")?;
        let dec_tok = batch[1].i32s().context("dec_tokens")?;
        let targets = batch[2].i32s().context("targets")?;
        let mask = batch[3].f32s().context("loss_mask")?;
        let b = *batch[0].shape.first().unwrap_or(&0);
        let le = *batch[0].shape.get(1).unwrap_or(&0);
        let ld = *batch[1].shape.get(1).unwrap_or(&0);
        if b == 0 || le == 0 || ld == 0 || batch[1].shape[0] != b {
            bail!("malformed lm batch shapes");
        }
        if batch[2].shape != batch[1].shape || batch[3].shape != batch[1].shape {
            bail!(
                "targets {:?} / loss_mask {:?} must match dec_tokens shape {:?}",
                batch[2].shape,
                batch[3].shape,
                batch[1].shape
            );
        }
        let (ne, nd) = (b * le, b * ld);
        let embed = self.pslice(params, "token_embed")?;
        let wc = self.pslice(params, "dec/cross_w")?;

        let gather = |toks: &[i32], n: usize| -> Result<Vec<f32>> {
            let mut h = vec![0f32; n * d];
            for (i, &t) in toks.iter().enumerate() {
                let t = t as usize;
                if t >= v {
                    bail!("token id {t} out of vocab range {v}");
                }
                h[i * d..(i + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
            }
            Ok(h)
        };

        // Encoder.
        let mut h_enc = gather(enc_tok, ne)?;
        let enc_run =
            self.tower_forward(params, &self.enc_blocks, &mut h_enc, ne, want_grads, ex)?;
        // Cross context: per-example mean of encoder outputs through cross_w.
        let mut c = vec![0f32; b * d];
        for bi in 0..b {
            for t in 0..le {
                for ch in 0..d {
                    c[bi * d + ch] += h_enc[(bi * le + t) * d + ch];
                }
            }
            for ch in 0..d {
                c[bi * d + ch] /= le as f32;
            }
        }
        let mut hc = vec![0f32; b * d];
        self.gemm.mm_nn(&c, wc, b, d, d, &mut hc);
        // Decoder.
        let mut h_dec = gather(dec_tok, nd)?;
        for bi in 0..b {
            for t in 0..ld {
                for ch in 0..d {
                    h_dec[(bi * ld + t) * d + ch] += hc[bi * d + ch];
                }
            }
        }
        let dec_run =
            self.tower_forward(params, &self.dec_blocks, &mut h_dec, nd, want_grads, ex)?;

        // Tied-embedding logits + masked cross-entropy (softmax in place;
        // raw logits are never needed again).
        let mut probs = vec![0f32; nd * v];
        self.gemm.mm_nt_big(&h_dec, embed, nd, d, v, &mut probs);
        softmax_rows(&mut probs, nd, v);
        let mask_sum: f64 = mask.iter().map(|&m| m as f64).sum();
        if mask_sum <= 0.0 {
            bail!("loss mask is all zero");
        }
        let mut loss = 0f64;
        let mut correct = 0f64;
        for i in 0..nd {
            if mask[i] <= 0.0 {
                continue;
            }
            let tgt = targets[i] as usize;
            if tgt >= v {
                bail!("target id {tgt} out of vocab range {v}");
            }
            let row = &probs[i * v..(i + 1) * v];
            loss -= (row[tgt].max(1e-30) as f64).ln() * mask[i] as f64;
            let mut am = 0usize;
            for (j, &p) in row.iter().enumerate() {
                if p > row[am] {
                    am = j;
                }
            }
            if am == tgt {
                correct += mask[i] as f64;
            }
        }
        loss /= mask_sum;
        let accuracy = correct / mask_sum;

        let aux_total = enc_run.aux + dec_run.aux;
        let moe_blocks = enc_run.moe_blocks + dec_run.moe_blocks;
        let mut metrics = Metrics::new();
        metrics.insert("loss".into(), loss);
        metrics.insert("accuracy".into(), accuracy);
        if self.entry.is_sparse() {
            metrics.insert("aux_loss".into(), aux_total);
            let blocks = moe_blocks.max(1) as f64;
            let cov_blocks = (enc_run.coverage_sum + dec_run.coverage_sum) / blocks;
            metrics.insert("coverage".into(), if moe_blocks > 0 { cov_blocks } else { 1.0 });
        }
        if !want_grads {
            return Ok((metrics, None));
        }

        // ---- backward ----
        let _ph = phase("backward");
        let mut grads: Vec<Vec<f32>> =
            self.entry.params.iter().map(|s| vec![0f32; s.shape.iter().product()]).collect();
        let inv = 1.0 / mask_sum as f32;
        let mut dlogits = vec![0f32; nd * v];
        for i in 0..nd {
            if mask[i] <= 0.0 {
                continue;
            }
            let tgt = targets[i] as usize;
            let w = mask[i] * inv;
            let p = &probs[i * v..(i + 1) * v];
            let drow = &mut dlogits[i * v..(i + 1) * v];
            for j in 0..v {
                drow[j] = p[j] * w;
            }
            drow[tgt] -= w;
        }
        let embed_idx = self.idx("token_embed")?;
        // Tied projection: dE += dlogitsᵀ·H, dH = dlogits·E.
        self.gemm.mm_tn_big(&dlogits, &h_dec, nd, v, d, &mut grads[embed_idx]);
        let mut dh_dec = vec![0f32; nd * d];
        self.gemm.mm_nn_big(&dlogits, embed, nd, v, d, &mut dh_dec);

        self.tower_backward(params, &self.dec_blocks, &dec_run, &mut dh_dec, nd, &mut grads, ex)?;

        // Decoder input = embedding + broadcast cross context.
        for (i, &t) in dec_tok.iter().enumerate() {
            accumulate(
                &mut grads[embed_idx][(t as usize) * d..(t as usize + 1) * d],
                &dh_dec[i * d..(i + 1) * d],
            );
        }
        let mut dhc = vec![0f32; b * d];
        for bi in 0..b {
            for t in 0..ld {
                for ch in 0..d {
                    dhc[bi * d + ch] += dh_dec[(bi * ld + t) * d + ch];
                }
            }
        }
        {
            let wc_idx = self.idx("dec/cross_w")?;
            self.gemm.mm_tn(&c, &dhc, b, d, d, &mut grads[wc_idx]);
        }
        let mut dc = vec![0f32; b * d];
        self.gemm.mm_nt(&dhc, wc, b, d, d, &mut dc);
        let mut dh_enc = vec![0f32; ne * d];
        let inv_le = 1.0 / le as f32;
        for bi in 0..b {
            for t in 0..le {
                for ch in 0..d {
                    dh_enc[(bi * le + t) * d + ch] += dc[bi * d + ch] * inv_le;
                }
            }
        }
        self.tower_backward(params, &self.enc_blocks, &enc_run, &mut dh_enc, ne, &mut grads, ex)?;
        for (i, &t) in enc_tok.iter().enumerate() {
            accumulate(
                &mut grads[embed_idx][(t as usize) * d..(t as usize + 1) * d],
                &dh_enc[i * d..(i + 1) * d],
            );
        }
        Ok((metrics, Some(grads)))
    }

    // -- vision model ------------------------------------------------------

    /// Extract patch rows from images [B,H,W,C] → [B·np, patch²·C].
    fn patches(&self, images: &Tensor) -> Result<(Vec<f32>, usize, usize)> {
        let cfg = &self.entry.config;
        let p = cfg.patch_size;
        if images.shape.len() != 4 {
            bail!("images must be [B,H,W,C], got {:?}", images.shape);
        }
        let (b, h, w, ch) = (images.shape[0], images.shape[1], images.shape[2], images.shape[3]);
        if p == 0 || h % p != 0 || w % p != 0 {
            bail!("image {h}x{w} not divisible by patch size {p}");
        }
        let px = images.f32s()?;
        let (ph, pw) = (h / p, w / p);
        let np = ph * pw;
        let plen = p * p * ch;
        let mut out = vec![0f32; b * np * plen];
        for bi in 0..b {
            for py in 0..ph {
                for pxi in 0..pw {
                    let patch_row = bi * np + py * pw + pxi;
                    for dy in 0..p {
                        for dx in 0..p {
                            let src = ((bi * h + py * p + dy) * w + pxi * p + dx) * ch;
                            let dst = patch_row * plen + (dy * p + dx) * ch;
                            out[dst..dst + ch].copy_from_slice(&px[src..src + ch]);
                        }
                    }
                }
            }
        }
        Ok((out, b, np))
    }

    /// Forward to the pooled representation. Returns (pooled [B,d], caches).
    fn vit_trunk(
        &self,
        params: &[Tensor],
        images: &Tensor,
        want_cache: bool,
        ex: &mut dyn ExpertExchange,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, TowerRun, usize, usize)> {
        let d = self.entry.config.d_model;
        let (pmat, b, np) = self.patches(images)?;
        let wp = self.pslice(params, "patch_embed/w")?;
        let plen = pmat.len() / (b * np);
        let n = b * np;
        let mut h = vec![0f32; n * d];
        self.gemm.mm_nn_big(&pmat, wp, n, plen, d, &mut h);
        let run = self.tower_forward(params, &self.enc_blocks, &mut h, n, want_cache, ex)?;
        let mut pooled = vec![0f32; b * d];
        for bi in 0..b {
            for t in 0..np {
                for ch in 0..d {
                    pooled[bi * d + ch] += h[(bi * np + t) * d + ch];
                }
            }
            for ch in 0..d {
                pooled[bi * d + ch] /= np as f32;
            }
        }
        Ok((pooled, h, pmat, run, b, np))
    }

    fn vit_step(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        want_grads: bool,
        ex: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Option<Vec<Vec<f32>>>)> {
        let cfg = &self.entry.config;
        let (d, nc) = (cfg.d_model, cfg.num_classes);
        if batch.len() != 2 {
            bail!("vit batch must be [images, labels]");
        }
        let labels = batch[1].i32s().context("labels")?;
        let (pooled, _h, pmat, run, b, np) = self.vit_trunk(params, &batch[0], want_grads, ex)?;
        if labels.len() != b {
            bail!("labels length {} != batch {b}", labels.len());
        }
        let wh = self.pslice(params, "head/w")?;
        let mut probs = vec![0f32; b * nc];
        self.gemm.mm_nn(&pooled, wh, b, d, nc, &mut probs);
        softmax_rows(&mut probs, b, nc);
        let mut loss = 0f64;
        let mut correct = 0usize;
        for bi in 0..b {
            let l = labels[bi] as usize;
            if l >= nc {
                bail!("label {l} out of range {nc}");
            }
            let row = &probs[bi * nc..(bi + 1) * nc];
            loss -= (row[l].max(1e-30) as f64).ln();
            let mut am = 0usize;
            for (j, &p) in row.iter().enumerate() {
                if p > row[am] {
                    am = j;
                }
            }
            if am == l {
                correct += 1;
            }
        }
        loss /= b as f64;
        let accuracy = correct as f64 / b as f64;

        let mut metrics = Metrics::new();
        metrics.insert("loss".into(), loss);
        metrics.insert("accuracy".into(), accuracy);
        if self.entry.is_sparse() {
            metrics.insert("aux_loss".into(), run.aux);
            metrics.insert(
                "coverage".into(),
                if run.moe_blocks > 0 { run.coverage_sum / run.moe_blocks as f64 } else { 1.0 },
            );
        }
        if !want_grads {
            return Ok((metrics, None));
        }

        let _ph = phase("backward");
        let mut grads: Vec<Vec<f32>> =
            self.entry.params.iter().map(|s| vec![0f32; s.shape.iter().product()]).collect();
        let inv = 1.0 / b as f32;
        let mut dlogits = vec![0f32; b * nc];
        for bi in 0..b {
            let l = labels[bi] as usize;
            let p = &probs[bi * nc..(bi + 1) * nc];
            let drow = &mut dlogits[bi * nc..(bi + 1) * nc];
            for j in 0..nc {
                drow[j] = p[j] * inv;
            }
            drow[l] -= inv;
        }
        {
            let wh_idx = self.idx("head/w")?;
            self.gemm.mm_tn(&pooled, &dlogits, b, d, nc, &mut grads[wh_idx]);
        }
        let mut dpooled = vec![0f32; b * d];
        self.gemm.mm_nt(&dlogits, wh, b, nc, d, &mut dpooled);
        let n = b * np;
        let mut dh = vec![0f32; n * d];
        let inv_np = 1.0 / np as f32;
        for bi in 0..b {
            for t in 0..np {
                for ch in 0..d {
                    dh[(bi * np + t) * d + ch] = dpooled[bi * d + ch] * inv_np;
                }
            }
        }
        self.tower_backward(params, &self.enc_blocks, &run, &mut dh, n, &mut grads, ex)?;
        let plen = pmat.len() / n;
        {
            let wp_idx = self.idx("patch_embed/w")?;
            self.gemm.mm_tn_big(&pmat, &dh, n, plen, d, &mut grads[wp_idx]);
        }
        Ok((metrics, Some(grads)))
    }

    // -- forward-only inference -------------------------------------------

    /// LM forward-only inference: `[enc_tokens, dec_tokens]` → argmax token
    /// per decoder position + per-example mean log-probability of the
    /// predicted tokens. `want_cache` stays false all the way down, so no
    /// backward caches or optimizer buffers are ever allocated — this is
    /// the serving-path memory footprint.
    fn lm_infer(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        ex: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        let cfg = &self.entry.config;
        let (d, v) = (cfg.d_model, cfg.vocab_size);
        if inputs.len() != 2 {
            bail!("lm inference inputs must be [enc_tokens, dec_tokens]");
        }
        let enc_tok = inputs[0].i32s().context("enc_tokens")?;
        let dec_tok = inputs[1].i32s().context("dec_tokens")?;
        let b = *inputs[0].shape.first().unwrap_or(&0);
        let le = *inputs[0].shape.get(1).unwrap_or(&0);
        let ld = *inputs[1].shape.get(1).unwrap_or(&0);
        if b == 0 || le == 0 || ld == 0 || inputs[1].shape[0] != b {
            bail!("malformed lm inference shapes");
        }
        let (ne, nd) = (b * le, b * ld);
        let embed = self.pslice(params, "token_embed")?;
        let wc = self.pslice(params, "dec/cross_w")?;
        let gather = |toks: &[i32], n: usize| -> Result<Vec<f32>> {
            let mut h = vec![0f32; n * d];
            for (i, &t) in toks.iter().enumerate() {
                let t = t as usize;
                if t >= v {
                    bail!("token id {t} out of vocab range {v}");
                }
                h[i * d..(i + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
            }
            Ok(h)
        };

        // Encoder → cross context → decoder: a restatement of `lm_step`'s
        // forward dataflow minus every cache and the loss bookkeeping —
        // kept separate so the (bitwise-pinned) training path stays
        // untouched; `infer_predictions_argmax_the_eval_distribution`
        // pins the two dataflows to each other.
        let mut h_enc = gather(enc_tok, ne)?;
        self.tower_forward(params, &self.enc_blocks, &mut h_enc, ne, false, ex)?;
        let mut c = vec![0f32; b * d];
        for bi in 0..b {
            for t in 0..le {
                for ch in 0..d {
                    c[bi * d + ch] += h_enc[(bi * le + t) * d + ch];
                }
            }
            for ch in 0..d {
                c[bi * d + ch] /= le as f32;
            }
        }
        let mut hc = vec![0f32; b * d];
        self.gemm.mm_nn(&c, wc, b, d, d, &mut hc);
        let mut h_dec = gather(dec_tok, nd)?;
        for bi in 0..b {
            for t in 0..ld {
                for ch in 0..d {
                    h_dec[(bi * ld + t) * d + ch] += hc[bi * d + ch];
                }
            }
        }
        self.tower_forward(params, &self.dec_blocks, &mut h_dec, nd, false, ex)?;

        // Tied-embedding logits → per-position argmax + log-probabilities.
        let mut probs = vec![0f32; nd * v];
        self.gemm.mm_nt_big(&h_dec, embed, nd, d, v, &mut probs);
        softmax_rows(&mut probs, nd, v);
        let mut preds = vec![0i32; nd];
        let mut scores = vec![0f32; b];
        for i in 0..nd {
            let row = &probs[i * v..(i + 1) * v];
            let mut am = 0usize;
            for (j, &p) in row.iter().enumerate() {
                if p > row[am] {
                    am = j;
                }
            }
            preds[i] = am as i32;
            scores[i / ld] += row[am].max(1e-30).ln();
        }
        for sc in scores.iter_mut() {
            *sc /= ld as f32;
        }
        Ok(InferOutput { predictions: Tensor::from_i32(&[b, ld], preds), scores })
    }

    /// Vision forward-only inference: `[images]` → argmax class +
    /// per-example log-probability of the predicted class.
    fn vit_infer(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        ex: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        let cfg = &self.entry.config;
        let (d, nc) = (cfg.d_model, cfg.num_classes);
        if inputs.len() != 1 {
            bail!("vit inference inputs must be [images]");
        }
        let (pooled, _h, _pmat, _run, b, _np) = self.vit_trunk(params, &inputs[0], false, ex)?;
        let wh = self.pslice(params, "head/w")?;
        let mut probs = vec![0f32; b * nc];
        self.gemm.mm_nn(&pooled, wh, b, d, nc, &mut probs);
        softmax_rows(&mut probs, b, nc);
        let mut preds = vec![0i32; b];
        let mut scores = vec![0f32; b];
        for bi in 0..b {
            let row = &probs[bi * nc..(bi + 1) * nc];
            let mut am = 0usize;
            for (j, &p) in row.iter().enumerate() {
                if p > row[am] {
                    am = j;
                }
            }
            preds[bi] = am as i32;
            scores[bi] = row[am].max(1e-30).ln();
        }
        Ok(InferOutput { predictions: Tensor::from_i32(&[b], preds), scores })
    }

    /// Forward-only inference entry. `exchange` overrides where the expert
    /// MLP executes (EP-sharded serving); `None` builds the in-process
    /// [`LocalExchange`].
    fn infer_impl(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        exchange: Option<&mut dyn ExpertExchange>,
    ) -> Result<InferOutput> {
        self.check_params(params)?;
        let mut local = LocalExchange::new(self, params);
        let ex: &mut dyn ExpertExchange = match exchange {
            Some(e) => {
                e.bind(self.gemm)?;
                e
            }
            None => &mut local,
        };
        if self.entry.family == "lm" {
            self.lm_infer(params, inputs, ex)
        } else {
            self.vit_infer(params, inputs, ex)
        }
    }

    /// Run one step. `exchange` overrides where the expert MLP executes
    /// (expert parallelism); `None` builds the in-process [`LocalExchange`].
    fn step(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        want_grads: bool,
        exchange: Option<&mut dyn ExpertExchange>,
    ) -> Result<(Metrics, Option<Vec<Vec<f32>>>)> {
        self.check_params(params)?;
        let mut local = LocalExchange::new(self, params);
        let ex: &mut dyn ExpertExchange = match exchange {
            Some(e) => {
                e.bind(self.gemm)?;
                e
            }
            None => &mut local,
        };
        if self.entry.family == "lm" {
            self.lm_step(params, batch, want_grads, ex)
        } else {
            self.vit_step(params, batch, want_grads, ex)
        }
    }

    /// Package raw gradient buffers as manifest-ordered tensors.
    fn grads_to_tensors(&self, grads: Vec<Vec<f32>>) -> Vec<Tensor> {
        self.entry
            .params
            .iter()
            .zip(grads)
            .map(|(s, g)| Tensor::from_f32(&s.shape, g))
            .collect()
    }
}

/// Elementwise `dst += src` (shared with the expert-parallel owner's
/// source-ordered weight-grad accumulation in `runtime::ep`).
pub(crate) fn accumulate(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

impl Executable for NativeExec {
    fn has(&self, kind: &str) -> bool {
        self.entry.artifacts.contains_key(kind)
    }

    fn train_step(
        &self,
        mut params: Vec<Tensor>,
        mut opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput> {
        let (metrics, grads) = self.step(&params, batch, true, None)?;
        let grads = grads.expect("grads requested");
        // Adam with decoupled weight decay; state layout (m, v) per param.
        // Shared with the data-parallel trainer's post-all-reduce update.
        let _ph = phase("optimizer");
        adam_update(&mut params, &mut opt_state, &grads, lr, wd, step)?;
        Ok(StepOutput { params, opt_state, metrics })
    }

    fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics> {
        Ok(self.step(params, batch, false, None)?.0)
    }

    fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor> {
        if self.entry.family != "vit" {
            bail!("features extraction is only available for vision models");
        }
        self.check_params(params)?;
        let d = self.entry.config.d_model;
        let mut local = LocalExchange::new(self, params);
        let (pooled, _h, _pmat, _run, b, _np) = self.vit_trunk(params, images, false, &mut local)?;
        Ok(Tensor::from_f32(&[b, d], pooled))
    }

    fn grads(&self, params: &[Tensor], batch: &[Tensor]) -> Result<(Metrics, Vec<Tensor>)> {
        let (metrics, grads) = self.step(params, batch, true, None)?;
        let grads = grads.expect("grads requested");
        Ok((metrics, self.grads_to_tensors(grads)))
    }

    fn grads_ep(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<(Metrics, Vec<Tensor>)> {
        let (metrics, grads) = self.step(params, batch, true, Some(exchange))?;
        let grads = grads.expect("grads requested");
        Ok((metrics, self.grads_to_tensors(grads)))
    }

    fn infer(&self, params: &[Tensor], inputs: &[Tensor]) -> Result<InferOutput> {
        self.infer_impl(params, inputs, None)
    }

    fn infer_ep(
        &self,
        params: &[Tensor],
        inputs: &[Tensor],
        exchange: &mut dyn ExpertExchange,
    ) -> Result<InferOutput> {
        self.infer_impl(params, inputs, Some(exchange))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{FlopsInfo, InitSpec, ModelConfig, TensorSpec};
    use crate::tensor::DType;
    use crate::util::rng::Rng;

    fn pspec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: DType::F32,
            init: Some(InitSpec { kind: "normal".to_string(), stddev: 0.1 }),
        }
    }

    /// Micro LM: V=8, d=4, ff=4; enc block 1 is MoE with E=2, C=2. With two
    /// experts and C=2, both EC (each expert takes all tokens) and top-2
    /// (each token takes both experts, capacity never binds) select
    /// everything, so the loss is differentiable everywhere and finite
    /// differences are exact for either router family.
    fn micro_entry(router: &str, renormalize: bool) -> ModelEntry {
        let moe = MoeSpec {
            num_experts: 2,
            capacity_factor: 2.0,
            router_type: router.to_string(),
            moe_layers: vec![1],
            group_size: 0,
            renormalize,
            bpr: false,
        };
        let mut params = vec![
            pspec("token_embed", &[8, 4]),
            pspec("dec/cross_w", &[4, 4]),
            pspec("dec/block_00/mlp/wi", &[4, 4]),
            pspec("dec/block_00/mlp/wo", &[4, 4]),
            pspec("enc/block_00/mlp/wi", &[4, 4]),
            pspec("enc/block_00/mlp/wo", &[4, 4]),
            pspec("enc/block_01/moe/router", &[4, 2]),
            pspec("enc/block_01/moe/wi", &[2, 4, 4]),
            pspec("enc/block_01/moe/wo", &[2, 4, 4]),
        ];
        params.sort_by(|a, b| a.name.cmp(&b.name));
        let opt_state: Vec<TensorSpec> = params
            .iter()
            .flat_map(|p| {
                vec![
                    TensorSpec {
                        name: format!("opt/{}/m", p.name),
                        shape: p.shape.clone(),
                        dtype: DType::F32,
                        init: None,
                    },
                    TensorSpec {
                        name: format!("opt/{}/v", p.name),
                        shape: p.shape.clone(),
                        dtype: DType::F32,
                        init: None,
                    },
                ]
            })
            .collect();
        let param_count = params.iter().map(|s| s.shape.iter().product::<usize>()).sum();
        let mut artifacts = BTreeMap::new();
        artifacts.insert("train".to_string(), "native".to_string());
        artifacts.insert("eval".to_string(), "native".to_string());
        ModelEntry {
            name: "micro".to_string(),
            family: "lm".to_string(),
            config: ModelConfig {
                family: "lm".to_string(),
                d_model: 4,
                d_ff: 4,
                num_heads: 1,
                num_layers: 2,
                num_decoder_layers: 1,
                vocab_size: 8,
                enc_len: 3,
                dec_len: 2,
                image_size: 0,
                patch_size: 0,
                channels: 0,
                num_classes: 0,
                batch_size: 2,
                enc_moe: Some(moe),
                dec_moe: None,
            },
            params,
            opt_state,
            batch: vec![
                TensorSpec {
                    name: "enc_tokens".to_string(),
                    shape: vec![2, 3],
                    dtype: DType::I32,
                    init: None,
                },
                TensorSpec {
                    name: "dec_tokens".to_string(),
                    shape: vec![2, 2],
                    dtype: DType::I32,
                    init: None,
                },
                TensorSpec {
                    name: "targets".to_string(),
                    shape: vec![2, 2],
                    dtype: DType::I32,
                    init: None,
                },
                TensorSpec {
                    name: "loss_mask".to_string(),
                    shape: vec![2, 2],
                    dtype: DType::F32,
                    init: None,
                },
            ],
            scalars: vec!["lr".to_string(), "wd".to_string(), "step".to_string()],
            metrics: vec![
                "accuracy".to_string(),
                "aux_loss".to_string(),
                "coverage".to_string(),
                "loss".to_string(),
            ],
            param_count,
            flops: FlopsInfo { train_step: 3.0, eval_step: 1.0, fwd_per_example: 1.0 },
            artifacts,
        }
    }

    fn micro_model(
        router: &str,
        renormalize: bool,
    ) -> (ModelEntry, LoadedModel, Vec<Tensor>, Vec<Tensor>) {
        let entry = micro_entry(router, renormalize);
        let mut models = BTreeMap::new();
        models.insert(entry.name.clone(), entry.clone());
        let manifest = Manifest {
            dir: std::path::PathBuf::new(),
            source_hash: "test".to_string(),
            models,
        };
        let model =
            NativeBackend::new().load_model(&manifest, "micro", &["train", "eval"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 3).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = vec![
            Tensor::from_i32(&[2, 3], vec![1, 5, 3, 2, 7, 4]),
            Tensor::from_i32(&[2, 2], vec![0, 6, 0, 2]),
            Tensor::from_i32(&[2, 2], vec![6, 1, 2, 1]),
            Tensor::from_f32(&[2, 2], vec![1.0, 1.0, 1.0, 0.0]),
        ];
        (entry, model, params, batch)
    }

    /// Hand-written backward vs central finite differences, across every
    /// parameter tensor (embedding, cross weight, dense MLP, router, expert
    /// weights) and across router families: Expert Choice, top-2 with and
    /// without combine-weight renormalization. The training objective is
    /// CE + AUX_COEF·aux, so the fd target is the same composite.
    #[test]
    fn gradients_match_finite_differences() {
        for (router, renorm) in [("ec", true), ("top2", true), ("top2", false)] {
            let (entry, model, params, batch) = micro_model(router, renorm);
            let objective = |m: &Metrics| -> f64 {
                m["loss"] + AUX_COEF as f64 * m.get("aux_loss").copied().unwrap_or(0.0)
            };
            let (metrics, grads) = model.grads(&params, &batch).unwrap();
            assert!(metrics["loss"].is_finite());
            let mut rng = Rng::new(4);
            let h = 1e-2f64;
            for (pi, spec) in entry.params.iter().enumerate() {
                let n = params[pi].numel();
                for _ in 0..3 {
                    let j = rng.below(n);
                    let mut pp = params.clone();
                    pp[pi].f32s_mut().unwrap()[j] += h as f32;
                    let lp = objective(&model.eval_step(&pp, &batch).unwrap());
                    let mut pm = params.clone();
                    pm[pi].f32s_mut().unwrap()[j] -= h as f32;
                    let lm = objective(&model.eval_step(&pm, &batch).unwrap());
                    let fd = ((lp - lm) / (2.0 * h)) as f32;
                    let an = grads[pi].f32s().unwrap()[j];
                    let tol = 2e-3 + 0.08 * an.abs().max(fd.abs());
                    assert!(
                        (fd - an).abs() < tol,
                        "grad mismatch [{router} renorm={renorm}] for {}[{j}]: \
                         fd {fd} vs analytic {an}",
                        spec.name
                    );
                }
            }
        }
    }

    /// Forward-only inference takes only the input tensors, is
    /// deterministic, and returns well-formed predictions and scores —
    /// across router families (EC and token choice).
    #[test]
    fn infer_runs_forward_only_and_is_deterministic() {
        for (router, renorm) in [("ec", true), ("top2", false)] {
            let (_entry, model, params, batch) = micro_model(router, renorm);
            let out = model.infer(&params, &batch[..2]).unwrap();
            assert_eq!(out.predictions.shape, vec![2, 2]);
            assert_eq!(out.scores.len(), 2);
            assert!(out.scores.iter().all(|sc| sc.is_finite() && *sc <= 0.0));
            for &p in out.predictions.i32s().unwrap() {
                assert!((0..8).contains(&p), "prediction {p} out of vocab");
            }
            let again = model.infer(&params, &batch[..2]).unwrap();
            assert_eq!(out, again, "inference must be deterministic");
            // Targets/masks are not part of the inference signature.
            assert!(model.infer(&params, &batch).is_err());
        }
    }

    /// The serving forward is pinned to the eval forward: feeding infer's
    /// own predictions back as eval targets (mask all-ones) must score
    /// exactly 100% accuracy — `lm_infer` re-states `lm_step`'s dataflow,
    /// and if the two ever drift their argmaxes disagree and this fails.
    #[test]
    fn infer_predictions_argmax_the_eval_distribution() {
        for (router, renorm) in [("ec", true), ("top2", true)] {
            let (_entry, model, params, batch) = micro_model(router, renorm);
            let out = model.infer(&params, &batch[..2]).unwrap();
            let eval_batch = vec![
                batch[0].clone(),
                batch[1].clone(),
                out.predictions.clone(),
                Tensor::ones(&batch[1].shape),
            ];
            let m = model.eval_step(&params, &eval_batch).unwrap();
            let acc = m["accuracy"];
            assert_eq!(acc, 1.0, "[{router}] serving argmax must match the eval distribution");
        }
    }

    #[test]
    fn train_step_reduces_micro_loss() {
        let (_entry, model, params, batch) = micro_model("ec", true);
        let mut params = params;
        let mut opt: Vec<Tensor> =
            model.entry.opt_state.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let l0 = model.eval_step(&params, &batch).unwrap()["loss"];
        for step in 1..=25u64 {
            let params_in = std::mem::take(&mut params);
            let opt_in = std::mem::take(&mut opt);
            let out = model.train_step(params_in, opt_in, &batch, 5e-3, 0.0, step).unwrap();
            params = out.params;
            opt = out.opt_state;
        }
        let l1 = model.eval_step(&params, &batch).unwrap()["loss"];
        assert!(l1 < l0 - 0.05, "overfitting one micro batch must reduce loss: {l0} -> {l1}");
    }

    fn uniform_probs(n: usize, e: usize, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0f32; n * e];
        for row in 0..n {
            let mut s = 0f32;
            for x in 0..e {
                let v = 0.1 + rng.f32();
                p[row * e + x] = v;
                s += v;
            }
            for x in 0..e {
                p[row * e + x] /= s;
            }
        }
        p
    }

    #[test]
    fn ec_routing_is_balanced_by_construction() {
        let spec = MoeSpec {
            num_experts: 8,
            capacity_factor: 2.0,
            router_type: "ec".to_string(),
            moe_layers: vec![0],
            group_size: 0,
            renormalize: false,
            bpr: false,
        };
        let mut rng = Rng::new(1);
        let probs = uniform_probs(64, 8, &mut rng);
        let r = route_tokens(&spec, &probs, 64);
        // Every expert takes exactly n·C/E = 16 tokens.
        for toks in &r.expert_tok {
            assert_eq!(toks.len(), 16);
        }
        assert!(!r.token_choice);
        assert_eq!(r.aux, 0.0);
        assert!(r.coverage > 0.5 && r.coverage <= 1.0);
    }

    #[test]
    fn top1_respects_capacity_and_reports_aux() {
        let spec = MoeSpec {
            num_experts: 4,
            capacity_factor: 1.0,
            router_type: "top1".to_string(),
            moe_layers: vec![0],
            group_size: 0,
            renormalize: false,
            bpr: false,
        };
        // Heavily skewed router: everyone loves expert 0.
        let n = 64;
        let mut probs = vec![0.05f32; n * 4];
        for t in 0..n {
            probs[t * 4] = 0.85;
        }
        let r = route_tokens(&spec, &probs, n);
        let cap = (n as f64 / 4.0).ceil() as usize;
        assert_eq!(r.expert_tok[0].len(), cap, "hot expert must be capped");
        assert!(r.token_choice);
        assert!(r.aux > 0.0, "skew must produce a positive balance penalty");
        assert!(r.coverage < 1.0, "capacity overflow must drop tokens");
    }

    #[test]
    fn routing_groups_partition_tokens() {
        let spec = MoeSpec {
            num_experts: 4,
            capacity_factor: 1.0,
            router_type: "ec".to_string(),
            moe_layers: vec![0],
            group_size: 16,
            renormalize: false,
            bpr: false,
        };
        let mut rng = Rng::new(2);
        let probs = uniform_probs(64, 4, &mut rng);
        let r = route_tokens(&spec, &probs, 64);
        // 4 groups of 16, each expert takes 16·1/4 = 4 per group.
        for toks in &r.expert_tok {
            assert_eq!(toks.len(), 16);
            for (i, &t) in toks.iter().enumerate() {
                assert_eq!(t / 16, i / 4, "assignments must stay within their group");
            }
        }
    }

    /// Blocked and reference kernels must produce the same training
    /// trajectory within float tolerance (the bench relies on the reference
    /// backend being a faithful scalar re-execution of the same model).
    #[test]
    fn reference_kernels_track_blocked_kernels() {
        let (entry, model, params, batch) = micro_model("top2", true);
        let mut models = BTreeMap::new();
        models.insert(entry.name.clone(), entry.clone());
        let manifest = Manifest {
            dir: std::path::PathBuf::new(),
            source_hash: "test".to_string(),
            models,
        };
        let scalar = NativeBackend::reference_kernels()
            .load_model(&manifest, "micro", &["train", "eval"])
            .unwrap();
        let mb = model.eval_step(&params, &batch).unwrap();
        let ms = scalar.eval_step(&params, &batch).unwrap();
        assert!(
            (mb["loss"] - ms["loss"]).abs() < 1e-4,
            "blocked {} vs reference {}",
            mb["loss"],
            ms["loss"]
        );
    }

    #[test]
    fn bpr_prioritizes_confident_tokens() {
        let spec = MoeSpec {
            num_experts: 2,
            capacity_factor: 0.5,
            router_type: "top2".to_string(),
            moe_layers: vec![0],
            group_size: 0,
            renormalize: true,
            bpr: true,
        };
        // Token 3 is the most confident; capacity is 1 slot per expert
        // (ceil(4·0.5·2/2) = 2)... with 4 tokens and cap 2, the two most
        // confident tokens win the slots.
        let probs = vec![
            0.55, 0.45, // t0
            0.60, 0.40, // t1
            0.52, 0.48, // t2
            0.95, 0.05, // t3 (most confident)
        ];
        let r = route_tokens(&spec, &probs, 4);
        assert!(
            r.expert_tok[0].contains(&3),
            "BPR must keep the most confident token: {:?}",
            r.expert_tok
        );
    }
}
