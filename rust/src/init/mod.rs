//! From-scratch parameter / optimizer-state initialization.
//!
//! Mirrors `python/compile/model.init_params` using the init specs recorded
//! in the manifest, so starting a dense pretraining run (or a
//! MoE-from-scratch baseline, Fig. 4) never touches Python at runtime.

use anyhow::{bail, Result};

use crate::checkpoint::Checkpoint;
use crate::manifest::{ModelEntry, TensorSpec};
use crate::tensor::{numel, Tensor};
use crate::util::rng::Rng;

pub fn init_tensor(spec: &TensorSpec, rng: &mut Rng) -> Result<Tensor> {
    let n = numel(&spec.shape);
    let init = spec
        .init
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("tensor `{}` has no init spec", spec.name))?;
    Ok(match init.kind.as_str() {
        "zeros" => Tensor::zeros(&spec.shape),
        "ones" => Tensor::ones(&spec.shape),
        "normal" | "fan_in" => {
            Tensor::from_f32(&spec.shape, rng.normal_vec(n, init.stddev))
        }
        k => bail!("unknown init kind `{k}` for `{}`", spec.name),
    })
}

/// Fresh parameter checkpoint for a model (step 0).
pub fn init_params(entry: &ModelEntry, seed: u64) -> Result<Checkpoint> {
    let mut rng = Rng::new(seed);
    let mut ck = Checkpoint::new(&entry.name, 0, "init: from scratch");
    for (i, spec) in entry.params.iter().enumerate() {
        // Independent stream per tensor: insertion order never changes values.
        let mut sub = rng.fork(i as u64);
        ck.insert(&spec.name, init_tensor(spec, &mut sub)?);
    }
    Ok(ck)
}

/// Zeroed Adafactor state for a model.
pub fn init_opt_state(entry: &ModelEntry) -> Result<Checkpoint> {
    let mut ck = Checkpoint::new(&entry.name, 0, "init: zero optimizer state");
    for spec in &entry.opt_state {
        ck.insert(&spec.name, Tensor::zeros(&spec.shape));
    }
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::InitSpec;
    use crate::tensor::DType;

    fn spec(name: &str, shape: &[usize], kind: &str, stddev: f32) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: DType::F32,
            init: Some(InitSpec { kind: kind.into(), stddev }),
        }
    }

    #[test]
    fn init_kinds() {
        let mut rng = Rng::new(0);
        let z = init_tensor(&spec("z", &[4], "zeros", 0.0), &mut rng).unwrap();
        assert_eq!(z.f32s().unwrap(), &[0.0; 4]);
        let o = init_tensor(&spec("o", &[3], "ones", 0.0), &mut rng).unwrap();
        assert_eq!(o.f32s().unwrap(), &[1.0; 3]);
        let n = init_tensor(&spec("n", &[4096], "normal", 0.02), &mut rng).unwrap();
        let std = (n.f32s().unwrap().iter().map(|x| x * x).sum::<f32>() / 4096.0).sqrt();
        assert!((std - 0.02).abs() < 0.002, "std {std}");
        assert!(init_tensor(&spec("b", &[1], "bogus", 0.0), &mut rng).is_err());
    }

    #[test]
    fn deterministic_across_calls() {
        let s = spec("w", &[64], "normal", 1.0);
        let a = init_tensor(&s, &mut Rng::new(5)).unwrap();
        let b = init_tensor(&s, &mut Rng::new(5)).unwrap();
        assert_eq!(a, b);
    }
}
