//! Ablation experiments (paper §4.2.2 + Appendix B.1–B.7).

use anyhow::Result;

use crate::metrics::{map, Report, Series};
use crate::upcycle::UpcycleOptions;

use super::Ctx;

/// Shared ablation skeleton: upcycle the LM parent into several sparse
/// variants and train each for the same extra budget.
fn sweep_upcycled(
    ctx: &Ctx,
    rep: &mut Report,
    dense_name: &str,
    variants: &[(&str, &str)],
    load_optimizer: bool,
) -> Result<()> {
    let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
    for (label, sparse_name) in variants {
        let (model, mut state) = ctx.branch_upcycle(
            &parent, sparse_name, &UpcycleOptions::default(), load_optimizer)?;
        rep.add(ctx.run_branch(&model, &mut state, 11, ctx.p.extra_steps, label)?);
    }
    Ok(())
}

/// Table 2 / Fig. 8: router type. Expert Choice vs Top-1 vs Top-2 (± BPR)
/// on the LM, plus EC vs Top-2 on vision (all beating dense continuation).
pub fn tab2(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("tab2", "Router type ablation (upcycled)");
    sweep_upcycled(
        ctx,
        &mut rep,
        "lm_tiny_dense",
        &[
            ("lm/expert_choice", "lm_tiny_moe_e8_c2"),
            ("lm/top2", "lm_tiny_moe_e8_c2_top2"),
            ("lm/top2_bpr", "lm_tiny_moe_e8_c2_top2bpr"),
            ("lm/top1", "lm_tiny_moe_e8_c2_top1"),
        ],
        false,
    )?;
    // Dense continuation reference row.
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    let (model, mut state) = ctx.branch_dense(&parent, "lm_tiny_dense")?;
    rep.add(ctx.run_branch(&model, &mut state, 12, ctx.p.extra_steps, "lm/dense")?);
    sweep_upcycled(
        ctx,
        &mut rep,
        "vit_tiny_dense",
        &[
            ("vit/expert_choice", "vit_tiny_moe_e8_c2"),
            ("vit/top2", "vit_tiny_moe_e8_c2_top2"),
        ],
        true,
    )?;
    rep.note("paper: EC ≥ Top-K per train-time; all routed variants beat dense");
    Ok(rep)
}

/// Fig. 9: expert capacity factor C ∈ {1, 2, 3}.
pub fn fig9(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig9", "Capacity factor ablation");
    sweep_upcycled(
        ctx,
        &mut rep,
        "lm_tiny_dense",
        &[
            ("C=1", "lm_tiny_moe_e8_c1"),
            ("C=2", "lm_tiny_moe_e8_c2"),
            ("C=3", "lm_tiny_moe_e8_c3"),
        ],
        false,
    )?;
    rep.note("x-axis (extra cost) stretches with C: higher C costs more per \
              step; paper: C=2 wins on a per-cost basis");
    Ok(rep)
}

/// Fig. 10: number of experts — training curves.
pub fn fig10(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig10", "Number of experts: training curves");
    sweep_upcycled(
        ctx,
        &mut rep,
        "lm_tiny_dense",
        &[
            ("E=2", "lm_tiny_moe_e2_c2"),
            ("E=4", "lm_tiny_moe_e4_c2"),
            ("E=8", "lm_tiny_moe_e8_c2"),
            ("E=16", "lm_tiny_moe_e16_c2"),
        ],
        false,
    )?;
    rep.note("experts are ~FLOPs-neutral (costmodel tests assert this); more \
              experts → more capacity");
    Ok(rep)
}

/// Fig. 11: number of experts — final up/downstream quality.
pub fn fig11(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig11", "Number of experts: final quality");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    let mut upstream = Series::new("upstream_accuracy");
    let mut downstream = Series::new("downstream_accuracy");
    for (e, name) in [(2, "lm_tiny_moe_e2_c2"), (4, "lm_tiny_moe_e4_c2"),
                      (8, "lm_tiny_moe_e8_c2"), (16, "lm_tiny_moe_e16_c2")] {
        let (model, mut state) = ctx.branch_upcycle(
            &parent, name, &UpcycleOptions::default(), false)?;
        let s = ctx.run_branch(&model, &mut state, 13, ctx.p.extra_steps, "run")?;
        let acc = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        upstream.push(e, 0.0, map(&[("value", acc)]));
        let ft = ctx.finetune_accuracy(&model, &mut state, 1e-3)?;
        downstream.push(e, 0.0, map(&[("value", ft)]));
    }
    rep.add(upstream);
    rep.add(downstream);
    rep.note("step axis = number of experts; paper: steady upstream gains, \
              diminishing downstream returns");
    Ok(rep)
}

/// Fig. 12: number of MoE layers (last-k + interleaved).
pub fn fig12(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig12", "Number of MoE layers");
    sweep_upcycled(
        ctx,
        &mut rep,
        "lm_tiny_dense",
        &[
            ("last-1", "lm_tiny_moe_last1"),
            ("last-2", "lm_tiny_moe_last2"),
            ("last-3", "lm_tiny_moe_last3"),
            ("every-other (2/4)", "lm_tiny_moe_e8_c2"),
        ],
        false,
    )?;
    rep.note("paper: ~half the layers sparsified is the sweet spot; more \
              layers cost more per step");
    Ok(rep)
}

/// Fig. 13: expert initialization — copied vs random.
pub fn fig13(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig13", "Expert init: copied (upcycled) vs random");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    for (label, load) in [("load_experts=true", true), ("load_experts=false", false)] {
        let opts = UpcycleOptions { load_experts: load, ..Default::default() };
        let (model, mut state) =
            ctx.branch_upcycle(&parent, "lm_tiny_moe_e8_c2", &opts, false)?;
        rep.add(ctx.run_branch(&model, &mut state, 14, ctx.p.extra_steps, label)?);
    }
    // Appendix B.9: small vs large expert noise.
    for (label, noise) in [("noise=0.01", 0.01f32), ("noise=0.2", 0.2)] {
        let opts = UpcycleOptions { expert_noise: noise, ..Default::default() };
        let (model, mut state) =
            ctx.branch_upcycle(&parent, "lm_tiny_moe_e8_c2", &opts, false)?;
        rep.add(ctx.run_branch(&model, &mut state, 15, ctx.p.extra_steps, label)?);
    }
    rep.note("paper: random experts need far more compute to catch up; small \
              noise ≈ no effect, large noise hurts (B.9)");
    Ok(rep)
}

/// Fig. 14: resuming the optimizer state (vision).
pub fn fig14(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig14", "Optimizer state resumption (vision)");
    let parent = ctx.dense_parent("vit_tiny_dense", ctx.p.pretrain_steps)?;
    for (label, load) in [("load_optimizer=true", true), ("load_optimizer=false", false)] {
        let (model, mut state) = ctx.branch_upcycle(
            &parent, "vit_tiny_moe_e8_c2", &UpcycleOptions::default(), load)?;
        rep.add(ctx.run_branch(&model, &mut state, 16, ctx.p.extra_steps, label)?);
    }
    rep.note("paper B.6: resuming Adafactor accumulators helps vision upcycling");
    Ok(rep)
}

/// Table 3: combine-weight renormalization, training V-MoE from scratch.
pub fn tab3(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("tab3", "Renormalization after routing (from scratch)");
    for (label, name) in [
        ("C=1/renorm", "vit_tiny_moe_e8_c1"),
        ("C=1/no_renorm", "vit_tiny_moe_e8_c1_norenorm"),
        ("C=2/renorm", "vit_tiny_moe_e8_c2"),
        ("C=2/no_renorm", "vit_tiny_moe_e8_c2_norenorm"),
    ] {
        let (model, mut state) = ctx.branch_scratch(name, ctx.p.seed + 5)?;
        rep.add(ctx.run_branch(&model, &mut state, 17, ctx.p.pretrain_steps, label)?);
    }
    rep.note("paper Table 3: renorm does not hurt from-scratch vision training \
              (and helps upcycling)");
    Ok(rep)
}
