//! Strategy zoo: every [`UpcycleStrategy`] on one dense parent, side by
//! side — initial quality, inter-expert diversity at init, surgery cost,
//! and a short continued-training run per branch.
//!
//! This is not a paper figure: the paper only studies replication
//! (Figure 1). The zoo places the follow-up surgery families —
//! Drop-Upcycling's partial re-init (arXiv 2502.19261), FFN splitting
//! ("Llama 3 Meets MoE"), and multi-checkpoint merging — on the same
//! footing so their trade-offs (identity preservation vs expert
//! diversity) are measurable on the tiny testbed.

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::coordinator::TrainState;
use crate::costmodel::surgery_cost;
use crate::metrics::{map, Report, Series};
use crate::upcycle::diversity::expert_diversity;
use crate::upcycle::{
    upcycle_opt_state, upcycle_params, SharedInit, UpcycleOptions, UpcycleStrategy,
};

use super::Ctx;

/// The `zoo` experiment: one row per strategy.
pub fn strategy_zoo(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("zoo", "Upcycle strategy zoo");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;

    // A differently-seeded dense sibling on disk: the extra source the
    // multi-checkpoint merge round-robins experts from.
    let second_path = ctx.ck_dir.join("strategy_zoo_second_parent.params.supc");
    let dense_entry = ctx.entry("lm_tiny_dense")?.clone();
    crate::init::init_params(&dense_entry, ctx.p.seed + 1)?.save(&second_path)?;

    let branches: Vec<(&str, &str, UpcycleStrategy)> = vec![
        ("replicate", "lm_tiny_moe_e8_c2", UpcycleStrategy::Replicate),
        (
            "drop_0.25",
            "lm_tiny_moe_e8_c2",
            UpcycleStrategy::DropUpcycle { reinit_fraction: 0.25, seed: ctx.p.seed },
        ),
        (
            "split_g1x8",
            "lm_tiny_moe_e8_c2",
            UpcycleStrategy::Split { granularity: 1, expansion: 8 },
        ),
        (
            "split_g2x4",
            "lm_tiny_moe_split_g2e8",
            UpcycleStrategy::Split { granularity: 2, expansion: 4 },
        ),
        (
            "multi_avg",
            "lm_tiny_moe_e8_c2",
            UpcycleStrategy::MultiCheckpoint {
                checkpoint_paths: vec![second_path.to_string_lossy().into_owned()],
                shared: SharedInit::Average,
            },
        ),
    ];

    let mut summary = Series::new("strategy_summary");
    for (i, (label, target, strategy)) in branches.iter().enumerate() {
        let entry = ctx.entry(target)?.clone();
        let opts =
            UpcycleOptions { strategy: strategy.clone(), seed: ctx.p.seed, ..Default::default() };
        // Surgery by hand (not `branch_upcycle`) so the upcycled params
        // checkpoint is still around for the diversity report.
        let params: Checkpoint = upcycle_params(&parent.0, &entry, &opts)?;
        let diversity = expert_diversity(&params, &entry)?;
        let opt = upcycle_opt_state(&parent.1, &entry, false, strategy)?;
        let model = ctx.load(target, &["train", "eval"])?;
        let mut state = TrainState::from_checkpoints(&entry, &params, &opt)?;
        let init = ctx.evaluator(&entry).eval(&model, &state)?;
        let series = ctx.run_branch(&model, &mut state, 29, ctx.p.extra_steps, label)?;
        let final_loss =
            series.last().and_then(|p| p.values.get("loss").copied()).unwrap_or(f64::NAN);
        let cost = surgery_cost(&entry, strategy);
        println!(
            "  {label}: init loss {:.4}, final loss {final_loss:.4}, \
             mean cosine diversity {:.6}",
            init.get("loss").copied().unwrap_or(f64::NAN),
            diversity.mean_cosine_distance()
        );
        summary.push(
            i as u64,
            0.0,
            map(&[
                ("init_loss", init.get("loss").copied().unwrap_or(f64::NAN)),
                ("final_loss", final_loss),
                ("mean_cosine_diversity", diversity.mean_cosine_distance()),
                ("mean_l2_diversity", diversity.mean_l2_distance()),
                ("surgery_bytes_copied", cost.bytes_copied as f64),
                ("surgery_values_reinitialized", cost.values_reinitialized as f64),
            ]),
        );
        rep.add(series);
    }
    rep.add(summary);
    rep.note(
        "step axis of strategy_summary = branch index (replicate, drop_0.25, \
         split_g1x8, split_g2x4, multi_avg); replicate and split_g1x8 have \
         exactly zero inter-expert diversity at init, drop/multi trade \
         identity for diversity (docs/UPCYCLING.md)",
    );
    Ok(rep)
}
