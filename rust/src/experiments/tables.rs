//! Tables 1, 4 and 5: parameter-count inventory and the selected-results
//! tables with relative extra-cost accounting.

use anyhow::Result;

use crate::coordinator::fewshot::{fewshot_accuracy, FewShotConfig};
use crate::costmodel::Cost;
use crate::metrics::{map, Report, Series};
use crate::upcycle::UpcycleOptions;

use super::Ctx;

/// Table 1: parameter counts, dense vs sparse, per family/variant.
pub fn tab1(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("tab1", "Model sizes (parameter counts)");
    let mut series = Series::new("param_counts");
    for (i, (_, entry)) in ctx.manifest.models.iter().enumerate() {
        series.push(i as u64, 0.0, map(&[
            ("params_million", entry.param_count as f64 / 1e6),
            ("expert_params_million", entry.expert_param_count() as f64 / 1e6),
            ("sparse", if entry.is_sparse() { 1.0 } else { 0.0 }),
        ]));
        rep.note(format!(
            "{:<28} {:<4} {:>8.2}M params ({}; experts {:.2}M)",
            entry.name,
            entry.family,
            entry.param_count as f64 / 1e6,
            if entry.is_sparse() { "sparse" } else { "dense" },
            entry.expert_param_count() as f64 / 1e6,
        ));
    }
    rep.add(series);
    rep.note("paper Table 1 analogue: sparse variants multiply parameters \
              while per-step FLOPs stay ~C× dense (see costmodel tests)");
    Ok(rep)
}

/// Shared machinery for Tables 4/5: rows of (method, extra cost, quality).
struct Row {
    method: String,
    extra: Cost,
    upstream: f64,
    downstream: f64,
}

fn table_rows(ctx: &Ctx, fam: &str, dense_name: &str, sparse_name: &str) -> Result<Vec<Row>> {
    let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
    let sunk = Cost::of_steps(ctx.entry(dense_name)?, ctx.p.pretrain_steps);
    let mut rows = Vec::new();

    let downstream = |ctx: &Ctx, model: &crate::runtime::LoadedModel,
                      state: &mut crate::coordinator::TrainState|
     -> Result<f64> {
        if fam == "vit" {
            // The 10-shot probe needs the `features` executable, which the
            // training branches do not compile; fetch it via the cache.
            let feats = ctx.load(&model.entry.name, &["features"])?;
            fewshot_accuracy(&feats, &state.params, &FewShotConfig::default(), ctx.p.seed)
        } else {
            ctx.finetune_accuracy(model, state, 1e-3)
        }
    };

    // Row 0: the original dense checkpoint (extra cost 0).
    {
        let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
        let m = ctx.evaluator(&model.entry).eval(&model, &state)?;
        let d = downstream(ctx, &model, &mut state)?;
        rows.push(Row {
            method: "dense (checkpoint)".into(),
            extra: Cost::zero(),
            upstream: *m.get("accuracy").unwrap_or(&f64::NAN),
            downstream: d,
        });
    }
    // Dense continuation.
    {
        let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
        let s = ctx.run_branch(&model, &mut state, 31, ctx.p.extra_steps, "d")?;
        let up = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        let extra = crate::coordinator::trainer::final_cost(&s);
        let d = downstream(ctx, &model, &mut state)?;
        rows.push(Row { method: "dense (continued)".into(), extra, upstream: up, downstream: d });
    }
    // Upcycled.
    {
        let (model, mut state) = ctx.branch_upcycle(
            &parent, sparse_name, &UpcycleOptions::default(), fam == "vit")?;
        let s = ctx.run_branch(&model, &mut state, 32, ctx.p.extra_steps, "u")?;
        let up = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        let extra = crate::coordinator::trainer::final_cost(&s);
        let d = downstream(ctx, &model, &mut state)?;
        rows.push(Row { method: "upcycled MoE".into(), extra, upstream: up, downstream: d });
    }
    // MoE from scratch (same extra budget — the paper's unflattering row).
    {
        let (model, mut state) = ctx.branch_scratch(sparse_name, ctx.p.seed + 3)?;
        let s = ctx.run_branch(&model, &mut state, 33, ctx.p.extra_steps, "s")?;
        let up = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        let extra = crate::coordinator::trainer::final_cost(&s);
        let d = downstream(ctx, &model, &mut state)?;
        rows.push(Row { method: "MoE from scratch".into(), extra, upstream: up, downstream: d });
    }

    // Pretty-print like the paper's table.
    println!("\n  {fam}: sunk dense cost = {:.4} core-days / {:.3} EFLOPs",
             sunk.core_days(), sunk.exaflops());
    println!("  {:<20} {:>10} {:>12} {:>12} {:>12}",
             "method", "upstream", "downstream", "extra c-days", "rel extra %");
    for r in &rows {
        println!(
            "  {:<20} {:>10.4} {:>12.4} {:>12.4} {:>12.1}",
            r.method, r.upstream, r.downstream,
            r.extra.core_days(), r.extra.relative_pct(&sunk),
        );
    }
    Ok(rows)
}

fn rows_into_report(rep: &mut Report, fam: &str, rows: Vec<Row>, sunk: Cost) {
    let mut series = Series::new(&format!("{fam}/selected_results"));
    for (i, r) in rows.iter().enumerate() {
        series.push(i as u64, r.extra.flops, map(&[
            ("upstream", r.upstream),
            ("downstream", r.downstream),
            ("relative_extra_pct", r.extra.relative_pct(&sunk)),
        ]));
        rep.note(format!(
            "{fam}/{}: upstream {:.4}, downstream {:.4}, extra {:.4} core-days \
             ({:.1}% of sunk)",
            r.method, r.upstream, r.downstream, r.extra.core_days(),
            r.extra.relative_pct(&sunk)
        ));
    }
    rep.add(series);
}

/// Table 4: selected vision results (upstream prec, 10-shot, cost columns).
pub fn tab4(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("tab4", "Selected vision results with cost accounting");
    let sunk = Cost::of_steps(ctx.entry("vit_tiny_dense")?, ctx.p.pretrain_steps);
    let rows = table_rows(ctx, "vit", "vit_tiny_dense", "vit_tiny_moe_e8_c2")?;
    rows_into_report(&mut rep, "vit", rows, sunk);
    rep.note("downstream column = 10-shot linear probe (5 seeds, ridge λ=1024), \
              paper §A.2.2");
    Ok(rep)
}

/// Table 5: selected language results (C4-analogue token accuracy,
/// downstream classification, cost columns).
pub fn tab5(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("tab5", "Selected language results with cost accounting");
    let sunk = Cost::of_steps(ctx.entry("lm_tiny_dense")?, ctx.p.pretrain_steps);
    let rows = table_rows(ctx, "lm", "lm_tiny_dense", "lm_tiny_moe_e8_c2")?;
    rows_into_report(&mut rep, "lm", rows, sunk);
    rep.note("upstream column = held-out span-corruption token accuracy \
              (the paper's C4 validation accuracy analogue)");
    Ok(rep)
}
