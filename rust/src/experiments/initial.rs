//! Initial-quality experiments (Appendix B.8): what happens at the very
//! first step after surgery — function preservation, group size, layer
//! placement and expert count vs the initial drop. These are evaluation-only
//! (no training), so they sweep cheaply.

use anyhow::Result;

use crate::metrics::{map, Report, Series};
use crate::upcycle::UpcycleOptions;

use super::Ctx;

/// Evaluate a freshly-upcycled model at step 0 (no training).
fn initial_metrics(
    ctx: &Ctx,
    parent: &(crate::checkpoint::Checkpoint, crate::checkpoint::Checkpoint),
    sparse_name: &str,
) -> Result<crate::runtime::Metrics> {
    let (model, state) = ctx.branch_upcycle_kinds(
        parent, sparse_name, &UpcycleOptions::default(), false, &["eval"])?;
    let evaluator = ctx.evaluator(&model.entry);
    evaluator.eval(&model, &state)
}

/// Dense parent's own metrics (the paper's horizontal reference).
fn dense_metrics(
    ctx: &Ctx,
    parent: &(crate::checkpoint::Checkpoint, crate::checkpoint::Checkpoint),
    dense_name: &str,
) -> Result<crate::runtime::Metrics> {
    let (model, state) = ctx.branch_dense(parent, dense_name)?;
    let evaluator = ctx.evaluator(&model.entry);
    evaluator.eval(&model, &state)
}

/// Fig. 15: initial quality vs capacity factor, ± combine-weight renorm.
/// With renorm and growing C the upcycled model approaches exact function
/// preservation (every token kept by ≥1 expert computes the dense output).
pub fn fig15(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new(
        "fig15", "Initial quality after surgery vs capacity factor");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    let dense = dense_metrics(ctx, &parent, "lm_tiny_dense")?;
    let mut base = Series::new("dense_parent");
    base.push(0, 0.0, dense.clone());
    rep.add(base);

    let mut no_renorm = Series::new("upcycled/no_renorm");
    for (c10, name) in [(10u64, "lm_tiny_moe_e8_c1"), (20, "lm_tiny_moe_e8_c2"),
                        (30, "lm_tiny_moe_e8_c3")] {
        let m = initial_metrics(ctx, &parent, name)?;
        no_renorm.push(c10, 0.0, m);
    }
    rep.add(no_renorm);

    let mut renorm = Series::new("upcycled/renorm");
    let m = initial_metrics(ctx, &parent, "lm_tiny_moe_e8_c2_renorm")?;
    renorm.push(20, 0.0, m);
    rep.add(renorm);

    rep.note("step axis = 10×capacity factor; paper Fig. 15: larger C + \
              renormalized combine weights retain the dense function");
    Ok(rep)
}

/// Fig. 16: routing group size — initial and post-training quality.
pub fn fig16(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig16", "Routing group size");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    for (label, name) in [
        ("group=16", "lm_tiny_moe_e8_c2_g16"),
        ("group=64", "lm_tiny_moe_e8_c2_g64"),
        ("group=all", "lm_tiny_moe_e8_c2"),
    ] {
        let (model, mut state) = ctx.branch_upcycle(
            &parent, name, &UpcycleOptions::default(), false)?;
        rep.add(ctx.run_branch(&model, &mut state, 21, ctx.p.extra_steps / 2, label)?);
    }
    rep.note("smaller groups → higher assignment variance → more dropped \
              tokens at the start (paper Fig. 16; EC is less sensitive)");
    Ok(rep)
}

/// Fig. 17: where the MoE layers go — initial drop by placement.
pub fn fig17(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig17", "MoE layer placement vs initial drop");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    let dense = dense_metrics(ctx, &parent, "lm_tiny_dense")?;
    let dense_loss = *dense.get("loss").unwrap_or(&f64::NAN);
    let mut series = Series::new("initial_loss_by_placement");
    for (i, (label, name)) in [
        ("first-2", "lm_tiny_moe_first2"),
        ("last-1", "lm_tiny_moe_last1"),
        ("last-2", "lm_tiny_moe_last2"),
        ("last-3", "lm_tiny_moe_last3"),
        ("interleaved-2", "lm_tiny_moe_e8_c2"),
    ]
    .iter()
    .enumerate()
    {
        let m = initial_metrics(ctx, &parent, name)?;
        let loss = *m.get("loss").unwrap_or(&f64::NAN);
        series.push(i as u64, 0.0, map(&[
            ("initial_loss", loss),
            ("drop_vs_dense", loss - dense_loss),
        ]));
        rep.note(format!("placement[{i}] = {label}: initial loss {loss:.4} \
                          (dense parent {dense_loss:.4})"));
    }
    rep.add(series);
    rep.note("paper Fig. 17: sparsifying the bottom layers causes the largest \
              initial drop; last-k / interleaved are gentlest");
    Ok(rep)
}

/// Fig. 18: number of experts vs initial drop.
pub fn fig18(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig18", "Number of experts vs initial drop");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
    let dense = dense_metrics(ctx, &parent, "lm_tiny_dense")?;
    let dense_loss = *dense.get("loss").unwrap_or(&f64::NAN);
    let mut series = Series::new("initial_loss_by_experts");
    for (e, name) in [(2u64, "lm_tiny_moe_e2_c2"), (4, "lm_tiny_moe_e4_c2"),
                      (8, "lm_tiny_moe_e8_c2"), (16, "lm_tiny_moe_e16_c2")] {
        let m = initial_metrics(ctx, &parent, name)?;
        let loss = *m.get("loss").unwrap_or(&f64::NAN);
        series.push(e, 0.0, map(&[
            ("initial_loss", loss),
            ("drop_vs_dense", loss - dense_loss),
        ]));
    }
    rep.add(series);
    rep.note("paper Fig. 18: more experts → heavier initial drop (recoverable, \
              Fig. 11)");
    Ok(rep)
}
