//! Experiment harness: one runner per paper figure/table (DESIGN.md §4).
//!
//! Every runner follows the paper's protocol shape: pretrain (or load a
//! cached) dense checkpoint, branch into dense-continuation / upcycled /
//! from-scratch arms, continue training each arm under the *continued* LR
//! schedule, evaluate on held-out shards, and report quality against extra
//! cost (simulated TPU-core-days / ExaFLOPs via `costmodel`). Dense parents
//! are cached under `checkpoints/` so the whole suite shares sunk cost —
//! exactly like the paper reuses its dense checkpoints.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::coordinator::{
    train, train_dp, train_mesh, train_mesh_elastic, DpConfig, Evaluator, MeshConfig, Schedule,
    TrainConfig, TrainState,
};
use crate::data::text::{HmmCorpus, HmmSpec, TextPipeline};
use crate::data::vision::{VisionPipeline, VisionSpec};
use crate::manifest::{Manifest, ModelEntry};
use crate::metrics::{Report, Series};
use crate::runtime::{LoadedModel, Runtime};
use crate::upcycle::{upcycle_opt_state, upcycle_params, UpcycleOptions};

mod ablations;
mod core_figs;
mod initial;
mod tables;
mod zoo;

/// Scale-dependent experiment knobs.
#[derive(Debug, Clone)]
pub struct ExpParams {
    pub pretrain_steps: u64,
    pub extra_steps: u64,
    pub finetune_steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub lm_peak_lr: f64,
    pub lm_warmup: u64,
    pub vit_peak_lr: f64,
    pub vit_warmup: u64,
    pub vit_weight_decay: f64,
    pub seed: u64,
}

impl ExpParams {
    pub fn tiny() -> ExpParams {
        ExpParams {
            pretrain_steps: 400,
            extra_steps: 240,
            finetune_steps: 120,
            eval_every: 60,
            eval_batches: 4,
            lm_peak_lr: 0.01,
            lm_warmup: 60,
            vit_peak_lr: 3e-3,
            vit_warmup: 60,
            vit_weight_decay: 1e-4,
            seed: 17,
        }
    }
}

/// How one branch's steps execute: single-worker, data-parallel, or on a
/// DP×EP mesh. One enum so every mode shares `run_branch_inner`'s setup
/// (pipeline, evaluator, schedule, weight decay) verbatim.
enum BranchExec<'a> {
    Single,
    Dp(&'a DpConfig),
    Mesh(&'a MeshConfig),
}

pub struct Ctx {
    pub runtime: Runtime,
    pub manifest: Manifest,
    pub out_dir: PathBuf,
    pub ck_dir: PathBuf,
    pub p: ExpParams,
    pub verbose: bool,
    /// In-process executable cache: XLA compilation of one train-step module
    /// takes ~30s on this CPU (see EXPERIMENTS.md §Perf), and the ablation
    /// suite revisits the same models repeatedly. `Mutex` + `Arc` (not
    /// `RefCell` + `Rc`) so cached handles can cross threads — the sweep
    /// scheduler's workers hand `LoadedModel`s to scoped worker threads.
    cache: std::sync::Mutex<BTreeMap<String, std::sync::Arc<LoadedModel>>>,
}

impl Ctx {
    pub fn new(artifacts: &str, out_dir: &str, p: ExpParams, verbose: bool) -> Result<Ctx> {
        let manifest = Manifest::load_or_native(artifacts)?;
        let runtime = Runtime::for_manifest(&manifest)?;
        Ok(Ctx {
            runtime,
            manifest,
            out_dir: PathBuf::from(out_dir),
            ck_dir: PathBuf::from(out_dir).join("checkpoints"),
            p,
            verbose,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    /// Compile-once model loading. On a cache hit that lacks a requested
    /// executable kind, the model is recompiled with the union of kinds.
    pub fn load(&self, name: &str, kinds: &[&str]) -> Result<std::sync::Arc<LoadedModel>> {
        // Union with whatever an earlier caller compiled so nothing is lost
        // on recompile — derived from the cached model's *actual* kinds,
        // not a hardcoded list (a compiled kind outside train/eval/features
        // used to be silently dropped here). The lock is never held across
        // the compile itself.
        let mut union: Vec<String> = kinds.iter().map(|k| k.to_string()).collect();
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            if kinds.iter().all(|k| m.has(k) || !m.entry.artifacts.contains_key(*k)) {
                return Ok(m.clone());
            }
            for k in m.entry.artifacts.keys() {
                if m.has(k) && !union.iter().any(|u| u == k) {
                    union.push(k.clone());
                }
            }
        }
        let union: Vec<&str> = union.iter().map(|k| k.as_str()).collect();
        let t0 = std::time::Instant::now();
        let model =
            std::sync::Arc::new(self.runtime.load_model(&self.manifest, name, &union)?);
        if self.verbose {
            println!("  compiled {name} {union:?} in {:.1}s", t0.elapsed().as_secs_f64());
        }
        self.cache.lock().unwrap().insert(name.to_string(), model.clone());
        Ok(model)
    }

    pub fn entry(&self, name: &str) -> Result<&ModelEntry> {
        self.manifest.model(name)
    }

    // ---- data -------------------------------------------------------------

    /// Pretraining corpus shared by every LM run (seed fixed per context).
    pub fn lm_corpus(&self, entry: &ModelEntry) -> HmmCorpus {
        HmmCorpus::new(
            HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
            self.p.seed ^ 0xc0ffee,
        )
    }

    pub fn lm_pipeline(&self, entry: &ModelEntry, shard: u64) -> TextPipeline {
        TextPipeline::new(
            self.lm_corpus(entry),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            self.p.seed,
            shard,
        )
    }

    /// Held-out LM evaluator (shard 1000, never used for training).
    pub fn lm_evaluator(&self, entry: &ModelEntry) -> Evaluator {
        let mut held_out = self.lm_pipeline(entry, 1000);
        Evaluator::from_source(&mut held_out, self.p.eval_batches)
    }

    pub fn vit_pipeline(&self, entry: &ModelEntry, shard: u64) -> VisionPipeline {
        VisionPipeline::new(
            VisionSpec { image_size: entry.config.image_size, ..Default::default() },
            entry.config.batch_size,
            self.p.seed,
            shard,
        )
    }

    pub fn vit_evaluator(&self, entry: &ModelEntry) -> Evaluator {
        let mut held_out = self.vit_pipeline(entry, 1000);
        Evaluator::from_source(&mut held_out, self.p.eval_batches)
    }

    pub fn pipeline(
        &self,
        entry: &ModelEntry,
        shard: u64,
    ) -> Box<dyn crate::coordinator::BatchSource> {
        if entry.family == "lm" {
            Box::new(self.lm_pipeline(entry, shard))
        } else {
            Box::new(self.vit_pipeline(entry, shard))
        }
    }

    pub fn evaluator(&self, entry: &ModelEntry) -> Evaluator {
        if entry.family == "lm" {
            self.lm_evaluator(entry)
        } else {
            self.vit_evaluator(entry)
        }
    }

    // ---- schedules ----------------------------------------------------------

    /// Pretraining schedule for a family; shared by the dense parent and
    /// every branch (the paper's continuity requirement, §4.1).
    pub fn schedule(&self, entry: &ModelEntry) -> Schedule {
        if entry.family == "lm" {
            Schedule::t5_pretrain(self.p.lm_peak_lr, self.p.lm_warmup)
        } else {
            Schedule::vit_pretrain(self.p.vit_peak_lr, self.p.vit_warmup, 4 * self.p.vit_warmup)
        }
    }

    pub fn weight_decay(&self, entry: &ModelEntry) -> f64 {
        if entry.family == "lm" {
            0.0
        } else {
            self.p.vit_weight_decay
        }
    }

    pub fn train_cfg(&self, steps: u64) -> TrainConfig {
        TrainConfig {
            steps,
            schedule: Schedule::constant(0.0), // overwritten by callers
            weight_decay: 0.0,
            eval_every: self.p.eval_every,
            log_every: if self.verbose { 50 } else { 0 },
        }
    }

    // ---- dense parents -------------------------------------------------------

    /// Pretrain (or load the cached) dense parent checkpoint at
    /// `steps`, returning (params, opt_state). Cached on disk so every
    /// figure shares the same sunk cost.
    pub fn dense_parent(&self, name: &str, steps: u64) -> Result<(Checkpoint, Checkpoint)> {
        let tag = format!("{name}_s{steps}_seed{}", self.p.seed);
        let p_path = self.ck_dir.join(format!("{tag}.params.supc"));
        let o_path = self.ck_dir.join(format!("{tag}.opt.supc"));
        if p_path.exists() && o_path.exists() {
            return Ok((Checkpoint::load(&p_path)?, Checkpoint::load(&o_path)?));
        }
        let entry = self.entry(name)?.clone();
        let model = self.load(name, &["train", "eval"])?;
        let mut state = TrainState::from_checkpoints(
            &entry,
            &crate::init::init_params(&entry, self.p.seed)?,
            &crate::init::init_opt_state(&entry)?,
        )?;
        let mut data = self.pipeline(&entry, 0);
        let evaluator = self.evaluator(&entry);
        let mut cfg = self.train_cfg(steps);
        cfg.schedule = self.schedule(&entry);
        cfg.weight_decay = self.weight_decay(&entry);
        println!("  pretraining dense parent `{name}` for {steps} steps...");
        let series = train(&model, &mut state, data.as_mut(), &evaluator, &cfg, "dense_pretrain")?;
        if let Some(p) = series.last() {
            println!(
                "  parent ready: loss={:.4} acc={:.4}",
                p.values.get("loss").unwrap_or(&f64::NAN),
                p.values.get("accuracy").unwrap_or(&f64::NAN)
            );
        }
        let (p, o) = state.to_checkpoints(&entry, "dense pretrain (parent)")?;
        p.save(&p_path)?;
        o.save(&o_path)?;
        Ok((p, o))
    }

    // ---- branches -------------------------------------------------------------

    /// Continue the dense parent as-is ("dense continuation" baseline).
    pub fn branch_dense(
        &self,
        parent: &(Checkpoint, Checkpoint),
        name: &str,
    ) -> Result<(std::sync::Arc<LoadedModel>, TrainState)> {
        let entry = self.entry(name)?.clone();
        let model = self.load(name, &["train", "eval"])?;
        let state = TrainState::from_checkpoints(&entry, &parent.0, &parent.1)?;
        Ok((model, state))
    }

    /// Upcycle the dense parent into `sparse_name` (paper Figure 1 surgery).
    pub fn branch_upcycle(
        &self,
        parent: &(Checkpoint, Checkpoint),
        sparse_name: &str,
        opts: &UpcycleOptions,
        load_optimizer: bool,
    ) -> Result<(std::sync::Arc<LoadedModel>, TrainState)> {
        self.branch_upcycle_kinds(parent, sparse_name, opts, load_optimizer, &["train", "eval"])
    }

    /// Like `branch_upcycle` but compiling only the given artifact kinds
    /// (the step-0 experiments of Appendix B.8 never train, and the XLA
    /// compile of a train module dominates their runtime otherwise).
    pub fn branch_upcycle_kinds(
        &self,
        parent: &(Checkpoint, Checkpoint),
        sparse_name: &str,
        opts: &UpcycleOptions,
        load_optimizer: bool,
        kinds: &[&str],
    ) -> Result<(std::sync::Arc<LoadedModel>, TrainState)> {
        let entry = self.entry(sparse_name)?.clone();
        let model = self.load(sparse_name, kinds)?;
        let params = upcycle_params(&parent.0, &entry, opts)
            .with_context(|| format!("upcycling into {sparse_name}"))?;
        let opt = upcycle_opt_state(&parent.1, &entry, load_optimizer, &opts.strategy)?;
        let state = TrainState::from_checkpoints(&entry, &params, &opt)?;
        Ok((model, state))
    }

    /// Fresh random init of `name` ("MoE from scratch" / dense-from-scratch).
    pub fn branch_scratch(
        &self,
        name: &str,
        seed: u64,
    ) -> Result<(std::sync::Arc<LoadedModel>, TrainState)> {
        let entry = self.entry(name)?.clone();
        let model = self.load(name, &["train", "eval"])?;
        let state = TrainState::from_checkpoints(
            &entry,
            &crate::init::init_params(&entry, seed)?,
            &crate::init::init_opt_state(&entry)?,
        )?;
        Ok((model, state))
    }

    /// Run one branch for `steps` under the family schedule; names the series.
    pub fn run_branch(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        shard: u64,
        steps: u64,
        series_name: &str,
    ) -> Result<Series> {
        self.run_branch_inner(model, state, shard, steps, BranchExec::Single, series_name)
    }

    /// [`Ctx::run_branch`], stepping each batch data-parallel under `dp`.
    pub fn run_branch_dp(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        shard: u64,
        steps: u64,
        dp: &DpConfig,
        series_name: &str,
    ) -> Result<Series> {
        self.run_branch_inner(model, state, shard, steps, BranchExec::Dp(dp), series_name)
    }

    /// [`Ctx::run_branch`] on a DP×EP mesh: token shards per rank, expert
    /// weights sharded over each group's EP ranks (see
    /// `coordinator::trainer::mesh_train_step`).
    pub fn run_branch_mesh(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        shard: u64,
        steps: u64,
        mesh: &MeshConfig,
        series_name: &str,
    ) -> Result<Series> {
        self.run_branch_inner(model, state, shard, steps, BranchExec::Mesh(mesh), series_name)
    }

    /// [`Ctx::run_branch_mesh`] with elasticity: periodic SUPC snapshots,
    /// rank-failure detection and rollback + replay recovery — optionally
    /// with a deterministic injected fault schedule (the CLI's
    /// `--snapshot-every` / `--inject-fault` path). See
    /// `coordinator::trainer::train_mesh_elastic` for the bitwise-recovery
    /// contract.
    #[allow(clippy::too_many_arguments)]
    pub fn run_branch_elastic(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        shard: u64,
        steps: u64,
        mesh: &MeshConfig,
        ecfg: &crate::resilience::ElasticConfig,
        series_name: &str,
    ) -> Result<(Series, crate::resilience::ElasticReport)> {
        let entry = &model.entry;
        let mut data = self.pipeline(entry, shard);
        let evaluator = self.evaluator(entry);
        let mut cfg = self.train_cfg(steps);
        cfg.schedule = self.schedule(entry);
        cfg.weight_decay = self.weight_decay(entry);
        train_mesh_elastic(
            model,
            state,
            data.as_mut(),
            &evaluator,
            &cfg,
            mesh,
            ecfg,
            series_name,
        )
    }

    fn run_branch_inner(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        shard: u64,
        steps: u64,
        exec: BranchExec<'_>,
        series_name: &str,
    ) -> Result<Series> {
        let entry = &model.entry;
        let mut data = self.pipeline(entry, shard);
        let evaluator = self.evaluator(entry);
        let mut cfg = self.train_cfg(steps);
        cfg.schedule = self.schedule(entry);
        cfg.weight_decay = self.weight_decay(entry);
        match exec {
            BranchExec::Single => train(model, state, data.as_mut(), &evaluator, &cfg, series_name),
            BranchExec::Dp(dp) => {
                train_dp(model, state, data.as_mut(), &evaluator, &cfg, dp, series_name)
            }
            BranchExec::Mesh(mesh) => {
                train_mesh(model, state, data.as_mut(), &evaluator, &cfg, mesh, series_name)
            }
        }
    }

    /// Finetune on the downstream task (topic classification for LM,
    /// the same 16-class task for ViT — §A.2) and return final accuracy.
    pub fn finetune_accuracy(
        &self,
        model: &LoadedModel,
        state: &mut TrainState,
        lr: f64,
    ) -> Result<f64> {
        let entry = model.entry.clone();
        let (mut data, evaluator): (Box<dyn crate::coordinator::BatchSource>, Evaluator) =
            if entry.family == "lm" {
                let mk = |shard| {
                    crate::data::text::ClassificationPipeline::new(
                        8,
                        entry.config.vocab_size,
                        entry.config.batch_size,
                        entry.config.enc_len,
                        entry.config.dec_len,
                        self.p.seed + shard,
                    )
                };
                let mut held = mk(1000);
                (Box::new(mk(0)), Evaluator::from_source(&mut held, self.p.eval_batches))
            } else {
                // Vision finetuning: a held-out seed family of the shapes task.
                let mk = |shard: u64| self.vit_pipeline(&entry, 500 + shard);
                let mut held = mk(1000);
                (Box::new(mk(0)), Evaluator::from_source(&mut held, self.p.eval_batches))
            };
        let mut cfg = self.train_cfg(self.p.finetune_steps);
        cfg.schedule = Schedule::constant(lr);
        cfg.eval_every = 0;
        let series = train(model, state, data.as_mut(), &evaluator, &cfg, "finetune")?;
        Ok(series
            .last()
            .and_then(|p| p.values.get("accuracy").copied())
            .unwrap_or(f64::NAN))
    }
}

/// Metric map → BTreeMap for Series::push.
pub fn vals(m: &crate::runtime::Metrics) -> BTreeMap<String, f64> {
    m.clone()
}

type Runner = fn(&Ctx) -> Result<Report>;

/// Registry of all experiments, in paper order.
pub fn registry() -> Vec<(&'static str, &'static str, Runner)> {
    vec![
        (
            "fig2",
            "pretrain quality vs extra cost: dense continuation vs upcycling",
            core_figs::fig2 as Runner,
        ),
        (
            "fig2long",
            "fig2 with a saturated dense parent (paper operating point)",
            core_figs::fig2long,
        ),
        ("fig3", "finetuned quality vs extra pretrain cost", core_figs::fig3),
        ("fig4", "upcycling vs MoE-from-scratch", core_figs::fig4),
        ("fig5", "sparse upcycling vs dense (depth-tiled) upcycling", core_figs::fig5),
        ("fig6", "upcycling gain vs amount of dense pretraining", core_figs::fig6),
        ("fig7", "training curves with cooldown branches", core_figs::fig7),
        ("tab1", "model parameter counts", tables::tab1),
        ("tab2", "router type ablation (Expert Choice vs Top-K)", ablations::tab2),
        ("fig9", "expert capacity factor ablation", ablations::fig9),
        ("fig10", "number of experts: training curves", ablations::fig10),
        ("fig11", "number of experts: final quality", ablations::fig11),
        ("fig12", "number of MoE layers", ablations::fig12),
        ("fig13", "expert init: copied vs random", ablations::fig13),
        ("fig14", "optimizer state resumption", ablations::fig14),
        ("tab3", "combine-weight renormalization (from scratch)", ablations::tab3),
        ("fig15", "initial quality vs capacity factor (function preservation)", initial::fig15),
        ("fig16", "routing group size", initial::fig16),
        ("fig17", "MoE layer placement vs initial drop", initial::fig17),
        ("fig18", "number of experts vs initial drop", initial::fig18),
        ("tab4", "selected vision results with cost accounting", tables::tab4),
        ("tab5", "selected language results with cost accounting", tables::tab5),
        (
            "zoo",
            "upcycle strategy zoo: replicate vs drop-upcycle vs split vs multi-checkpoint",
            zoo::strategy_zoo,
        ),
    ]
}

pub fn run_by_id(ctx: &Ctx, id: &str) -> Result<Report> {
    for (rid, _, f) in registry() {
        if rid == id {
            return f(ctx);
        }
    }
    bail!("unknown experiment `{id}`; use `list` to see ids")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, Executable, Metrics, StepOutput};
    use crate::tensor::Tensor;
    use std::sync::{Arc, Mutex};

    /// Executable that only "has" the kinds it was compiled with — unlike
    /// the native backend, whose compilation is free and which therefore
    /// builds every kind regardless of the request (making cache recompile
    /// behavior unobservable through it).
    struct KindExec {
        kinds: Vec<String>,
    }

    impl Executable for KindExec {
        fn has(&self, kind: &str) -> bool {
            self.kinds.iter().any(|k| k == kind)
        }
        fn train_step(
            &self,
            _params: Vec<Tensor>,
            _opt_state: Vec<Tensor>,
            _batch: &[Tensor],
            _lr: f64,
            _wd: f64,
            _step: u64,
        ) -> Result<StepOutput> {
            bail!("stub executable")
        }
        fn eval_step(&self, _params: &[Tensor], _batch: &[Tensor]) -> Result<Metrics> {
            bail!("stub executable")
        }
        fn features(&self, _params: &[Tensor], _images: &Tensor) -> Result<Tensor> {
            bail!("stub executable")
        }
    }

    /// Kind-respecting backend: compiles exactly the requested kinds and
    /// logs each compile so the test can count them.
    struct KindBackend {
        log: Arc<Mutex<Vec<Vec<String>>>>,
    }

    impl Backend for KindBackend {
        fn platform(&self) -> String {
            "stub".to_string()
        }
        fn load_model(
            &self,
            manifest: &Manifest,
            name: &str,
            kinds: &[&str],
        ) -> Result<LoadedModel> {
            let kinds: Vec<String> = kinds.iter().map(|k| k.to_string()).collect();
            self.log.lock().unwrap().push(kinds.clone());
            Ok(LoadedModel::new(manifest.model(name)?.clone(), Box::new(KindExec { kinds })))
        }
    }

    #[test]
    fn recompile_unions_every_cached_kind_not_a_hardcoded_list() {
        let mut manifest = Manifest::native();
        // A kind outside the old hardcoded ["train","eval","features"]
        // union list: the regression this test pins down is that a cached
        // executable for such a kind was silently dropped on recompile.
        let entry = manifest.models.get_mut("lm_tiny_dense").unwrap();
        entry.artifacts.insert("probe".to_string(), "probe.hlo".to_string());

        let log = Arc::new(Mutex::new(Vec::new()));
        let dir = std::env::temp_dir().join("supc_ctx_union_test");
        let ctx = Ctx {
            runtime: Runtime::from_backend(Box::new(KindBackend { log: log.clone() })),
            manifest,
            out_dir: dir.clone(),
            ck_dir: dir.join("checkpoints"),
            p: ExpParams::tiny(),
            verbose: false,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        };

        // First load compiles only the probe kind.
        let m1 = ctx.load("lm_tiny_dense", &["probe"]).unwrap();
        assert!(m1.has("probe") && !m1.has("train"));

        // Asking for train/eval forces a recompile; the cached probe
        // executable must survive the union.
        let m2 = ctx.load("lm_tiny_dense", &["train", "eval"]).unwrap();
        assert!(m2.has("train") && m2.has("eval"));
        assert!(m2.has("probe"), "recompile dropped the cached `probe` kind");

        // Everything is now cached — no third compile.
        let m3 = ctx.load("lm_tiny_dense", &["probe", "train"]).unwrap();
        assert!(Arc::ptr_eq(&m2, &m3));

        let log = log.lock().unwrap();
        assert_eq!(log.len(), 2, "expected exactly 2 compiles, got {log:?}");
        assert_eq!(log[0], vec!["probe".to_string()]);
        assert!(log[1].contains(&"train".to_string()));
        assert!(log[1].contains(&"probe".to_string()));
    }
}
