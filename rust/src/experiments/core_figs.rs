//! Core result figures (paper §4.2.1): Figs. 2–7.

use anyhow::Result;

use crate::metrics::{map, Report, Series};
use crate::upcycle::UpcycleOptions;

use super::Ctx;

/// Family pairs (dense parent, default sparse target) used by the core figs.
fn families() -> Vec<(&'static str, &'static str, &'static str)> {
    vec![
        ("lm", "lm_tiny_dense", "lm_tiny_moe_e8_c2"),
        ("vit", "vit_tiny_dense", "vit_tiny_moe_e8_c2"),
    ]
}

/// Fig. 2: pretraining quality vs extra cost, dense continuation vs
/// upcycling, for both families.
pub fn fig2(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig2", "Pretrain quality vs extra pretraining cost");
    for (fam, dense_name, sparse_name) in families() {
        let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
        // Dense continuation.
        let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
        let mut s = ctx.run_branch(&model, &mut state, 1, ctx.p.extra_steps,
                                   &format!("{fam}/dense_continuation"))?;
        rep.add(std::mem::take(&mut s));
        // Upcycled (optimizer state resumed for vision only — §3.1).
        let (model, mut state) = ctx.branch_upcycle(
            &parent, sparse_name, &UpcycleOptions::default(), fam == "vit")?;
        let s = ctx.run_branch(&model, &mut state, 2, ctx.p.extra_steps,
                               &format!("{fam}/upcycled"))?;
        rep.add(s);
    }
    rep.note(format!(
        "dense parent pretrained {} steps; branches +{} steps; paper shape: \
         upcycled ≥ dense continuation once extra budget is non-trivial",
        ctx.p.pretrain_steps, ctx.p.extra_steps
    ));
    Ok(rep)
}

/// Fig. 2 at the paper's operating point: the paper upcycles *plateaued*
/// dense checkpoints (T5 Base: 1M steps to "plateauing performance", §A.1.1)
/// and applies +20..100% extra budget. `fig2` above uses the fast suite
/// defaults where both branches are still on the steep early slope and the
/// paper itself predicts near-parity; this variant trains the dense parent
/// ~5× longer (to saturation under the decayed LR) before branching, which
/// is where the capacity advantage of the upcycled MoE shows up.
pub fn fig2long(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new(
        "fig2long", "Fig. 2 with a saturated dense parent (paper operating point)");
    let pretrain = ctx.p.pretrain_steps * 5;
    let extra = ctx.p.extra_steps * 3;
    let (dense_name, sparse_name) = ("lm_tiny_dense", "lm_tiny_moe_e8_c2");
    let parent = ctx.dense_parent(dense_name, pretrain)?;
    let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
    rep.add(ctx.run_branch(&model, &mut state, 41, extra, "lm/dense_continuation")?);
    let (model, mut state) = ctx.branch_upcycle(
        &parent, sparse_name, &UpcycleOptions::default(), false)?;
    rep.add(ctx.run_branch(&model, &mut state, 42, extra, "lm/upcycled")?);
    rep.note(format!(
        "parent pretrained {pretrain} steps (≈ plateau), branches +{extra} steps; \
         paper shape: upcycled pulls ahead once the dense branch saturates"
    ));
    Ok(rep)
}

/// Fig. 3: downstream (finetuned) quality of snapshots along each branch.
pub fn fig3(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig3", "Finetuned quality vs extra pretraining cost");
    let segments = 3u64;
    for (fam, dense_name, sparse_name) in families() {
        let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
        for (branch, sparse) in [("dense_continuation", false), ("upcycled", true)] {
            let (model, mut state) = if sparse {
                ctx.branch_upcycle(&parent, sparse_name, &UpcycleOptions::default(),
                                   fam == "vit")?
            } else {
                ctx.branch_dense(&parent, dense_name)?
            };
            let mut series = Series::new(&format!("{fam}/{branch}"));
            let seg_steps = ctx.p.extra_steps / segments;
            let mut extra = 0.0;
            for seg in 0..segments {
                let s = ctx.run_branch(&model, &mut state, 1 + seg, seg_steps,
                                       "segment")?;
                extra += s.last().map(|p| p.extra_flops).unwrap_or(0.0);
                // Finetune a *copy* of the snapshot (finetuning must not
                // perturb the pretraining trajectory).
                let (p_ck, o_ck) = state.to_checkpoints(&model.entry, "snapshot")?;
                let mut ft_state = crate::coordinator::TrainState::from_checkpoints(
                    &model.entry, &p_ck, &o_ck)?;
                let lr = if fam == "lm" { 1e-3 } else { 3e-4 };
                let acc = ctx.finetune_accuracy(&model, &mut ft_state, lr)?;
                series.push(state.step, extra, map(&[("finetune_accuracy", acc)]));
            }
            rep.add(series);
        }
    }
    rep.note("each point: snapshot finetuned on the downstream task \
              (topic classification / held-out shapes family)");
    Ok(rep)
}

/// Fig. 4: upcycling vs training the same MoE from scratch.
pub fn fig4(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig4", "Upcycling vs MoE trained from scratch");
    // From-scratch arms get a larger budget (the paper trains them past
    // 100% of the dense parent's cost to find the crossover).
    let scratch_steps = ctx.p.pretrain_steps + ctx.p.extra_steps;
    for (fam, dense_name, sparse_name) in families() {
        let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
        let (model, mut state) = ctx.branch_upcycle(
            &parent, sparse_name, &UpcycleOptions::default(), fam == "vit")?;
        rep.add(ctx.run_branch(&model, &mut state, 2, ctx.p.extra_steps,
                               &format!("{fam}/upcycled"))?);
        let (model, mut state) = ctx.branch_scratch(sparse_name, ctx.p.seed + 99)?;
        rep.add(ctx.run_branch(&model, &mut state, 3, scratch_steps,
                               &format!("{fam}/moe_from_scratch"))?);
    }
    rep.note("x-axis is extra cost over the dense checkpoint; the scratch arm \
              reuses no sunk cost, so it needs ≳100% of the parent budget to catch up");
    Ok(rep)
}

/// Fig. 5: sparse upcycling vs dense upcycling (depth tiling).
pub fn fig5(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig5", "Sparse vs dense (depth-tiled) upcycling");
    let dense_name = "lm_tiny_dense";
    let tiled_name = "lm_tiny_dense_tiled";
    let sparse_name = "lm_tiny_moe_e8_c2";
    let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;

    let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
    rep.add(ctx.run_branch(&model, &mut state, 1, ctx.p.extra_steps, "dense_continuation")?);

    let (model, mut state) = ctx.branch_upcycle(
        &parent, sparse_name, &UpcycleOptions::default(), false)?;
    rep.add(ctx.run_branch(&model, &mut state, 2, ctx.p.extra_steps, "sparse_upcycled")?);

    // Dense upcycling: depth-tile the parent into the 1.5× deeper model.
    let dense_entry = ctx.entry(dense_name)?.clone();
    let tiled_entry = ctx.entry(tiled_name)?.clone();
    let tiled_params = crate::upcycle::depth_tile_params(&parent.0, &dense_entry, &tiled_entry)?;
    let tiled_opt = crate::init::init_opt_state(&tiled_entry)?;
    let model = ctx.load(tiled_name, &["train", "eval"])?;
    let mut state = crate::coordinator::TrainState::from_checkpoints(
        &tiled_entry, &tiled_params, &tiled_opt)?;
    state.step = parent.0.step;
    rep.add(ctx.run_branch(&model, &mut state, 3, ctx.p.extra_steps, "dense_upcycled_tiled")?);

    rep.note("depth tiling per Rae et al. 2021; paper finds it gains over the \
              parent but underperforms sparse upcycling");
    Ok(rep)
}

/// Fig. 6: upcycling gain vs how long the dense parent was pretrained.
pub fn fig6(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig6", "Upcycling vs amount of dense pretraining");
    // Vision, C=1 (paper: comparable per-step cost for dense and sparse).
    let dense_name = "vit_tiny_dense";
    let sparse_name = "vit_tiny_moe_e8_c1";
    let fractions = [0.25, 0.5, 0.75, 1.0];
    let extra = ctx.p.extra_steps / 2;
    let mut dense_series = Series::new("dense_continuation");
    let mut up_series = Series::new("upcycled");
    for frac in fractions {
        let steps = ((ctx.p.pretrain_steps as f64) * frac) as u64;
        let parent = ctx.dense_parent(dense_name, steps)?;
        let (model, mut state) = ctx.branch_dense(&parent, dense_name)?;
        let s = ctx.run_branch(&model, &mut state, 1, extra, "d")?;
        let acc = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        dense_series.push(steps, 0.0, map(&[("accuracy_after_extra", acc)]));

        let (model, mut state) = ctx.branch_upcycle(
            &parent, sparse_name, &UpcycleOptions::default(), true)?;
        let s = ctx.run_branch(&model, &mut state, 2, extra, "u")?;
        let acc = s.last().and_then(|p| p.values.get("accuracy").copied()).unwrap_or(f64::NAN);
        up_series.push(steps, 0.0, map(&[("accuracy_after_extra", acc)]));
    }
    rep.add(dense_series);
    rep.add(up_series);
    rep.note(format!(
        "x = parent pretraining steps; y = quality after +{extra} further steps; \
         paper shape: the upcycling gain is roughly constant in parent training"
    ));
    Ok(rep)
}

/// Fig. 7 (appendix): combined curves with LR cooldowns at several budgets.
pub fn fig7(ctx: &Ctx) -> Result<Report> {
    let mut rep = Report::new("fig7", "Training curves with cooldown branches");
    let dense_name = "vit_tiny_dense";
    let sparse_name = "vit_tiny_moe_e8_c2";
    let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
    for (branch, sparse) in [("dense", false), ("upcycled", true)] {
        for frac in [0.5f64, 1.0] {
            let steps = (ctx.p.extra_steps as f64 * frac) as u64;
            let cooldown = (steps / 4).max(10);
            let (model, mut state) = if sparse {
                ctx.branch_upcycle(&parent, sparse_name, &UpcycleOptions::default(), true)?
            } else {
                ctx.branch_dense(&parent, dense_name)?
            };
            let entry = model.entry.clone();
            let mut data = ctx.pipeline(&entry, 7);
            let evaluator = ctx.evaluator(&entry);
            let mut cfg = ctx.train_cfg(steps);
            cfg.schedule = ctx
                .schedule(&entry)
                .with_cooldown(state.step + steps - cooldown, cooldown);
            cfg.weight_decay = ctx.weight_decay(&entry);
            let name = format!("{branch}/budget_{:.0}%", 100.0 * frac);
            rep.add(crate::coordinator::train(
                &model, &mut state, data.as_mut(), &evaluator, &cfg, &name)?);
        }
    }
    rep.note("each branch ends with a linear cooldown to 0 (paper Fig. 7); the \
              upcycled slope exceeds the dense one");
    Ok(rep)
}
