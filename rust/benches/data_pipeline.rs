//! Bench: synthetic data substrates. The data path runs on the host between
//! device steps, so it must stay far cheaper than a train step (~40 ms at
//! tiny scale); these benches keep it honest (EXPERIMENTS.md §Perf).
//!
//! Run: cargo bench --bench data_pipeline
//! (How to run + interpret all benches: docs/BENCHMARKS.md.)

use sparse_upcycle::data::text::{span_corrupt, HmmCorpus, HmmSpec, TextPipeline};
use sparse_upcycle::data::vision::{VisionPipeline, VisionSpec};
use sparse_upcycle::util::bench::bench;
use sparse_upcycle::util::rng::Rng;

fn main() {
    println!("== text pipeline ==");
    let corpus = HmmCorpus::new(HmmSpec::default(), 1);
    let mut rng = Rng::new(2);
    let r = bench("hmm_corpus.sample(40 tokens)", 200, || {
        std::hint::black_box(corpus.sample(40, &mut rng));
    });
    r.throughput(40.0, "tokens");

    let raw = corpus.sample(40, &mut rng);
    bench("span_corrupt(40 -> 32/16)", 200, || {
        std::hint::black_box(span_corrupt(&raw, 256, 32, 16, &mut rng));
    });

    let mut pipe = TextPipeline::new(HmmCorpus::new(HmmSpec::default(), 1), 8, 32, 16, 3, 0);
    let r = bench("text_pipeline.next_batch (b=8, 32/16)", 300, || {
        std::hint::black_box(pipe.next_batch());
    });
    r.throughput(8.0 * 32.0, "enc-tokens");

    // Larger scale (the e2e `small` geometry).
    let big = HmmCorpus::new(HmmSpec { vocab_size: 8192, ..Default::default() }, 1);
    let mut pipe = TextPipeline::new(big, 8, 128, 32, 3, 0);
    let r = bench("text_pipeline.next_batch (b=8, 128/32, v=8192)", 300, || {
        std::hint::black_box(pipe.next_batch());
    });
    r.throughput(8.0 * 128.0, "enc-tokens");

    println!("\n== vision pipeline ==");
    let mut pipe = VisionPipeline::new(VisionSpec::default(), 16, 3, 0);
    let r = bench("vision_pipeline.next_batch (b=16, 32x32)", 300, || {
        std::hint::black_box(pipe.next_batch());
    });
    r.throughput(16.0, "images");

    let mut pipe = VisionPipeline::new(VisionSpec::default(), 1, 3, 0);
    bench("vision class_balanced(10-shot x 16 classes)", 300, || {
        std::hint::black_box(pipe.class_balanced(10));
    });
}
