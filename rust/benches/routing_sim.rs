//! Bench + report: expert-parallel routing simulation (paper §A.4).
//!
//! Sweeps the placement/traffic simulator over expert counts, capacity
//! factors and mesh sizes, reporting the quantities behind the paper's
//! parallelization discussion: load imbalance (Expert Choice is balanced by
//! construction; token-choice is not), all-to-all volume, and per-device
//! memory from `mesh` placement.
//!
//! Run: cargo bench --bench routing_sim
//! (How to run + interpret all benches: docs/BENCHMARKS.md.)

use sparse_upcycle::manifest::{Manifest, MoeSpec};
use sparse_upcycle::parallel::{place, simulate_routing, MeshSpec};
use sparse_upcycle::util::bench::bench;
use sparse_upcycle::util::rng::Rng;

fn spec(e: usize, c: f64, router: &str) -> MoeSpec {
    MoeSpec {
        num_experts: e,
        capacity_factor: c,
        router_type: router.into(),
        moe_layers: vec![1, 3],
        group_size: 0,
        renormalize: false,
        bpr: false,
    }
}

fn main() {
    let mesh = MeshSpec { data_parallel: 2, expert_parallel: 4, model_parallel: 1 };
    let mut rng = Rng::new(7);

    println!("== routing traffic (4096 tokens, d_model=64, mesh dp=2 ep=4) ==");
    println!("{:<26} {:>10} {:>12} {:>12}", "router", "imbalance", "a2a MB", "dispatched");
    for (e, c, r) in [
        (8, 1.0, "ec"), (8, 2.0, "ec"), (32, 2.0, "ec"),
        (8, 1.0, "top2"), (8, 2.0, "top2"), (32, 2.0, "top2"), (8, 1.0, "top1"),
    ] {
        let s = spec(e, c, r);
        let t = simulate_routing(&s, 4096, &mesh, &mut rng);
        println!(
            "{:<26} {:>10.3} {:>12.3} {:>12}",
            format!("{r} E={e} C={c}"),
            t.imbalance,
            t.all_to_all_bytes(64) as f64 / 1e6,
            t.dispatched_tokens
        );
    }

    println!("\n== simulator throughput ==");
    let s = spec(32, 2.0, "top2");
    let r = bench("simulate_routing(top2, E=32, 4096 tok)", 300, || {
        std::hint::black_box(simulate_routing(&s, 4096, &mesh, &mut rng));
    });
    r.throughput(4096.0, "tokens");
    let s = spec(32, 2.0, "ec");
    bench("simulate_routing(ec, E=32, 4096 tok)", 300, || {
        std::hint::black_box(simulate_routing(&s, 4096, &mesh, &mut rng));
    });

    if let Ok(manifest) = Manifest::load_or_native("artifacts") {
        println!("\n== placement (manifest models, mesh dp=2 ep=4 mp=1) ==");
        for name in ["lm_tiny_moe_e8_c2", "lm_tiny_moe_e16_c2", "lm_small_moe_e8_c2"] {
            if let Ok(entry) = manifest.model(name) {
                let p = place(entry, &mesh);
                println!(
                    "{:<26} experts/dev {:?}  expert-bytes/dev {:.2} MB  dense {:.2} MB",
                    name,
                    p.experts_per_device,
                    p.expert_param_bytes_per_device as f64 / 1e6,
                    p.dense_param_bytes as f64 / 1e6
                );
            }
        }
    }
}
