//! Bench: checkpoint surgery (the paper's algorithm) — upcycling, optimizer
//! state carry-over and depth tiling on real manifest geometries. The
//! surgery is a one-shot cost in practice; this bench guards against it
//! becoming accidentally quadratic as the expert count grows.
//!
//! Run: cargo bench --bench surgery
//! (How to run + interpret all benches: docs/BENCHMARKS.md.)

use sparse_upcycle::checkpoint::Checkpoint;
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::upcycle::{
    depth_tile_params, upcycle_opt_state, upcycle_params, UpcycleOptions, UpcycleStrategy,
};
use sparse_upcycle::util::bench::bench;

fn main() {
    let manifest = match Manifest::load_or_native("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping surgery bench (bad artifacts): {e}");
            return;
        }
    };
    let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
    let dense: Checkpoint = init_params(&dense_entry, 0).unwrap();
    let dense_opt: Checkpoint = init_opt_state(&dense_entry).unwrap();

    println!("== surgery benches (dense parent: {:.2}M params) ==",
             dense_entry.param_count as f64 / 1e6);
    for target in ["lm_tiny_moe_e2_c2", "lm_tiny_moe_e8_c2", "lm_tiny_moe_e16_c2"] {
        let sparse = manifest.model(target).unwrap().clone();
        let r = bench(&format!("upcycle_params -> {target}"), 300, || {
            let ck = upcycle_params(&dense, &sparse, &UpcycleOptions::default()).unwrap();
            std::hint::black_box(ck.total_bytes());
        });
        r.throughput(sparse.param_count as f64, "params");
    }

    let sparse = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    bench("upcycle_params (noise σ=0.01)", 300, || {
        let opts = UpcycleOptions { expert_noise: 0.01, ..Default::default() };
        std::hint::black_box(upcycle_params(&dense, &sparse, &opts).unwrap());
    });
    bench("upcycle_opt_state (load_optimizer=true)", 300, || {
        std::hint::black_box(
            upcycle_opt_state(&dense_opt, &sparse, true, &UpcycleStrategy::Replicate).unwrap(),
        );
    });

    bench("upcycle_params (drop-upcycle, fraction 0.5)", 300, || {
        let opts = UpcycleOptions {
            strategy: UpcycleStrategy::DropUpcycle { reinit_fraction: 0.5, seed: 1 },
            ..Default::default()
        };
        std::hint::black_box(upcycle_params(&dense, &sparse, &opts).unwrap());
    });
    let split_target = manifest.model("lm_tiny_moe_split_g2e8").unwrap().clone();
    bench("upcycle_params (split g=2, x=4)", 300, || {
        let opts = UpcycleOptions {
            strategy: UpcycleStrategy::Split { granularity: 2, expansion: 4 },
            ..Default::default()
        };
        std::hint::black_box(upcycle_params(&dense, &split_target, &opts).unwrap());
    });

    let tiled = manifest.model("lm_tiny_dense_tiled").unwrap().clone();
    bench("depth_tile_params (4 -> 6 blocks)", 300, || {
        std::hint::black_box(depth_tile_params(&dense, &dense_entry, &tiled).unwrap());
    });
}
