//! Bench: end-to-end training/eval step cost through the execution backend —
//! the L3 hot path — and the writer of the machine-readable
//! **`BENCH_runtime.json`** baseline (schema + recorded numbers:
//! `docs/BENCHMARKS.md`). This regenerates the paper's per-step cost claims:
//!
//! * Fig. 9 / §2.1: a sparse step costs ≈ C× the dense MLP FLOPs + router,
//!   so dense < C=1 < C=2 < C=3;
//! * §3.1 "number of experts": E is ~FLOPs-neutral (E=2 vs E=16 ≈ same);
//!
//! and it is the measurement harness for the §Perf optimization loop. Every
//! run measures, on this machine:
//!
//! * step/eval latency percentiles + tokens/sec per zoo variant,
//! * the blocked-kernel speedup against the preserved PR 1 scalar path
//!   (`NativeBackend::reference_kernels`), at both the kernel and the
//!   full-train-step level,
//! * the per-phase breakdown (router / dispatch / expert_mlp / combine /
//!   backward / optimizer) via the `util::bench` phase profiler,
//! * data-parallel scaling (`coordinator::dp_train_step`) over worker
//!   replicas,
//! * expert-parallel DP×EP mesh scaling (`coordinator::mesh_train_step`):
//!   serial-vs-threaded mesh step time, the dispatch/alltoall/expert_mlp
//!   phase split, and the measured all-to-all exchange time against the
//!   `Interconnect::shared_memory` cost model,
//! * the overlap sweep: the same mesh step at microbatch counts 1/2/4,
//!   recording the *exposed* `ep_alltoall` window (blocking
//!   `finish_exchange` legs only) as the split-phase pipeline hides more
//!   of the exchange behind expert compute,
//! * the SIMD inference tier (`linalg::simd`) against the blocked kernels
//!   on the same zoo-shaped GEMMs — the speedup floor the bench gate pins,
//! * forward-only inference (`runtime::Executable::infer`): a batch-size
//!   sweep (latency percentiles, tokens/s) and the serve engine's
//!   continuous-batching throughput against unbatched serving on the same
//!   fixed arrival trace (`serve::Engine`),
//! * quantized inference (`--precision bf16|int8`): tokens/s on the SIMD
//!   runtime plus argmax agreement and mean score delta against f32 — the
//!   measured accuracy-vs-throughput trade (`checkpoint::quant`),
//! * the serving-load sweep (`serve::trafficgen`): one bursty multi-tenant
//!   trace replayed through every scheduler policy under a bounded queue,
//!   recording virtual p99/p999 tail latency, shed rate, and per-tenant
//!   goodput.
//!
//! Run: cargo bench --bench runtime_step [-- --full] [--quick]
//!      [--json-out PATH]   (default PATH: BENCH_runtime.json in the bench
//!      CWD, i.e. `rust/`)

use sparse_upcycle::checkpoint::quant::{quantize_params, Precision};
use sparse_upcycle::coordinator::{
    dp_train_step, mesh_train_step, BatchSource, DpConfig, MeshConfig, TrainState,
};
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::linalg::{gemm, simd};
use sparse_upcycle::manifest::{Manifest, ModelEntry};
use sparse_upcycle::parallel::collectives::Interconnect;
use sparse_upcycle::runtime::native::NativeBackend;
use sparse_upcycle::runtime::{Backend, LoadedModel, Runtime};
use sparse_upcycle::serve;
use sparse_upcycle::sweep;
use sparse_upcycle::util::bench::{
    bench, phases_enable, phases_reset, phases_snapshot, BenchResult,
};
use sparse_upcycle::util::json::{arr, num, obj, s, Json};

fn pipeline(entry: &ModelEntry) -> Box<dyn sparse_upcycle::coordinator::BatchSource> {
    if entry.family == "lm" {
        Box::new(sparse_upcycle::data::text::TextPipeline::new(
            sparse_upcycle::data::text::HmmCorpus::new(
                sparse_upcycle::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        ))
    } else {
        Box::new(sparse_upcycle::data::vision::VisionPipeline::new(
            sparse_upcycle::data::vision::VisionSpec::default(),
            entry.config.batch_size,
            1,
            0,
        ))
    }
}

fn fresh_state(entry: &ModelEntry) -> TrainState {
    TrainState::from_checkpoints(
        entry,
        &init_params(entry, 0).unwrap(),
        &init_opt_state(entry).unwrap(),
    )
    .unwrap()
}

/// Tokens processed per training step (the throughput denominator).
fn tokens_per_step(entry: &ModelEntry) -> f64 {
    let c = &entry.config;
    if entry.family == "lm" {
        (c.batch_size * (c.enc_len + c.dec_len)) as f64
    } else {
        let np = (c.image_size / c.patch_size.max(1)).pow(2);
        (c.batch_size * np) as f64
    }
}

fn result_json(r: &BenchResult, items_per_iter: f64, flops_per_iter: f64) -> Json {
    obj(vec![
        ("iters", num(r.iters as f64)),
        ("mean_ns", num(r.mean_ns)),
        ("p50_ns", num(r.p50_ns)),
        ("p90_ns", num(r.p90_ns)),
        ("p99_ns", num(r.p99_ns)),
        ("min_ns", num(r.min_ns)),
        ("stddev_ns", num(r.stddev_ns)),
        ("per_s", num(1e9 / r.mean_ns)),
        ("items_per_s", num(items_per_iter * 1e9 / r.mean_ns)),
        ("gflops_per_s", num(flops_per_iter / r.mean_ns)),
    ])
}

/// Bench one model's train loop, threading the optimizer state through
/// `state` (which stays warmed for later sections).
fn bench_train(
    name: &str,
    model: &LoadedModel,
    state: &mut TrainState,
    batch: &[sparse_upcycle::tensor::Tensor],
    target_ms: u64,
) -> BenchResult {
    let mut step = 0u64;
    bench(name, target_ms, || {
        step += 1;
        let params = std::mem::take(&mut state.params);
        let opt = std::mem::take(&mut state.opt_state);
        let out = model.train_step(params, opt, batch, 1e-3, 0.0, step).unwrap();
        state.params = out.params;
        state.opt_state = out.opt_state;
    })
}

/// Kernel-level blocked vs scalar comparison on zoo-shaped GEMMs.
fn kernel_section(target_ms: u64) -> Json {
    println!("== kernels: blocked vs PR 1 scalar reference ==");
    let mut rng = sparse_upcycle::util::rng::Rng::new(42);
    let mut shapes = Vec::new();
    // (n, k, m): token×d·ff MLP, token×d·vocab logits, small-geometry logits.
    for &(n, k, m) in &[(256usize, 32usize, 64usize), (128, 32, 256), (256, 64, 1024)] {
        let a: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; n * m];
        let rb = bench(&format!("mm_nn blocked {n}x{k}x{m}"), target_ms, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            gemm::mm_nn(&a, &b, n, k, m, &mut out);
        });
        let rr = bench(&format!("mm_nn scalar  {n}x{k}x{m}"), target_ms, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            gemm::reference::mm_nn(&a, &b, n, k, m, &mut out);
        });
        // The transposed-product form (logits / activation grads).
        let bt: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut out_nt = vec![0f32; n * m];
        let ntb = bench(&format!("mm_nt blocked {n}x{k}x{m}"), target_ms, || {
            out_nt.iter_mut().for_each(|v| *v = 0.0);
            gemm::mm_nt(&a, &bt, n, k, m, &mut out_nt);
        });
        let ntr = bench(&format!("mm_nt scalar  {n}x{k}x{m}"), target_ms, || {
            out_nt.iter_mut().for_each(|v| *v = 0.0);
            gemm::reference::mm_nt(&a, &bt, n, k, m, &mut out_nt);
        });
        println!(
            "  ↳ {n}x{k}x{m}: mm_nn speedup {:.2}x, mm_nt speedup {:.2}x\n",
            rr.mean_ns / rb.mean_ns,
            ntr.mean_ns / ntb.mean_ns
        );
        shapes.push(obj(vec![
            ("n", num(n as f64)),
            ("k", num(k as f64)),
            ("m", num(m as f64)),
            ("mm_nn_blocked_ns", num(rb.mean_ns)),
            ("mm_nn_reference_ns", num(rr.mean_ns)),
            ("mm_nn_speedup", num(rr.mean_ns / rb.mean_ns)),
            ("mm_nt_blocked_ns", num(ntb.mean_ns)),
            ("mm_nt_reference_ns", num(ntr.mean_ns)),
            ("mm_nt_speedup", num(ntr.mean_ns / ntb.mean_ns)),
        ]));
    }
    obj(vec![("shapes", arr(shapes))])
}

/// Vectorized-tier comparison: the SIMD inference kernels vs the blocked
/// training kernels on the same zoo-shaped GEMMs as `kernel_section`. The
/// large logits shape (256×64×1024) is where register blocking pays; the
/// gate floor in BENCH_baseline.json is pinned on that shape's `mm_nn`.
fn simd_section(target_ms: u64) -> Json {
    println!("== kernels: simd inference tier vs blocked ==");
    let mut rng = sparse_upcycle::util::rng::Rng::new(43);
    let mut shapes = Vec::new();
    for &(n, k, m) in &[(256usize, 32usize, 64usize), (128, 32, 256), (256, 64, 1024)] {
        let a: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; n * m];
        let rs = bench(&format!("mm_nn simd    {n}x{k}x{m}"), target_ms, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            simd::mm_nn(&a, &b, n, k, m, &mut out);
        });
        let rb = bench(&format!("mm_nn blocked {n}x{k}x{m}"), target_ms, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            gemm::mm_nn(&a, &b, n, k, m, &mut out);
        });
        let bt: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut out_nt = vec![0f32; n * m];
        let nts = bench(&format!("mm_nt simd    {n}x{k}x{m}"), target_ms, || {
            out_nt.iter_mut().for_each(|v| *v = 0.0);
            simd::mm_nt(&a, &bt, n, k, m, &mut out_nt);
        });
        let ntb = bench(&format!("mm_nt blocked {n}x{k}x{m}"), target_ms, || {
            out_nt.iter_mut().for_each(|v| *v = 0.0);
            gemm::mm_nt(&a, &bt, n, k, m, &mut out_nt);
        });
        println!(
            "  ↳ {n}x{k}x{m}: mm_nn simd speedup {:.2}x, mm_nt simd speedup {:.2}x\n",
            rb.mean_ns / rs.mean_ns,
            ntb.mean_ns / nts.mean_ns
        );
        shapes.push(obj(vec![
            ("n", num(n as f64)),
            ("k", num(k as f64)),
            ("m", num(m as f64)),
            ("mm_nn_simd_ns", num(rs.mean_ns)),
            ("mm_nn_blocked_ns", num(rb.mean_ns)),
            ("mm_nn_speedup_vs_blocked", num(rb.mean_ns / rs.mean_ns)),
            ("mm_nt_simd_ns", num(nts.mean_ns)),
            ("mm_nt_blocked_ns", num(ntb.mean_ns)),
            ("mm_nt_speedup_vs_blocked", num(ntb.mean_ns / nts.mean_ns)),
        ]));
    }
    let avx2 = cfg!(all(feature = "simd", target_arch = "x86_64"));
    obj(vec![("avx2_feature_compiled", Json::Bool(avx2)), ("shapes", arr(shapes))])
}

/// Quantized inference: the accuracy-vs-throughput trade of `--precision`,
/// measured on the SIMD-kernel runtime the CLI actually serves quantized
/// weights with. Weights are quantized once outside the timed region
/// (matching the serve path), and each precision reports tokens/s plus its
/// argmax agreement and mean |score delta| against the f32 run on the same
/// fixed batch.
fn quantized_inference_section(manifest: &Manifest, target_ms: u64) -> Json {
    println!("== inference: quantized weights (--precision) ==");
    let name = "lm_tiny_moe_e8_c2";
    let entry = manifest.model(name).unwrap().clone();
    let runtime = Runtime::native_simd().unwrap();
    let model = runtime.load_model(manifest, name, &["eval"]).unwrap();
    let state = fresh_state(&entry);
    let params = &state.params;

    let b = 8usize.min(entry.config.batch_size);
    let trace = serve::synthetic_trace(&entry, b, 5, 0);
    let inputs = serve::stack_inputs(&trace).unwrap();
    let tokens = (entry.config.enc_len + entry.config.dec_len) as f64 * b as f64;

    let full = model.infer(params, &inputs).unwrap();
    let full_preds = full.predictions.i32s().unwrap().to_vec();
    let mut precisions = Vec::new();
    for p in [Precision::F32, Precision::Bf16, Precision::Int8PerChannel] {
        let q = quantize_params(&entry, params, p).unwrap();
        let out = model.infer(&q, &inputs).unwrap();
        let preds = out.predictions.i32s().unwrap();
        let agree = full_preds.iter().zip(preds).filter(|(x, y)| x == y).count() as f64
            / full_preds.len().max(1) as f64;
        let mean_delta = full
            .scores
            .iter()
            .zip(&out.scores)
            .map(|(x, y)| (x - y).abs() as f64)
            .sum::<f64>()
            / full.scores.len().max(1) as f64;
        let r = bench(&format!("infer {name} b{b} {}", p.as_str()), target_ms, || {
            std::hint::black_box(model.infer(&q, &inputs).unwrap());
        });
        println!(
            "  ↳ {}: {:.1} tokens/s, argmax agreement {:.3}, mean |score Δ| {:.4}",
            p.as_str(),
            tokens * 1e9 / r.mean_ns,
            agree,
            mean_delta
        );
        precisions.push(obj(vec![
            ("precision", s(p.as_str())),
            ("mean_ns", num(r.mean_ns)),
            ("p50_ns", num(r.p50_ns)),
            ("tokens_per_s", num(tokens * 1e9 / r.mean_ns)),
            ("argmax_agreement_vs_f32", num(agree)),
            ("mean_score_delta_vs_f32", num(mean_delta)),
        ]));
    }
    println!();
    obj(vec![
        ("model", s(name)),
        ("platform", s(&runtime.platform())),
        ("batch", num(b as f64)),
        ("tokens_per_batch", num(tokens)),
        ("precisions", arr(precisions)),
    ])
}

/// Analytic all-to-all payload of one mesh step (Expert Choice): per MoE
/// block, every rank dispatches `E·c` rows of `d` floats; each of the 4
/// exchanges per block (fwd/bwd × out/ret) moves `rows·d·4 / ep` bytes per
/// peer. Returns the `Interconnect::shared_memory` prediction for the
/// whole step, summed over every rank's exchanges (matching how the phase
/// profiler accumulates the measured time across rank threads).
fn alltoall_model_ns_per_step(entry: &ModelEntry, mesh: &MeshConfig) -> f64 {
    let ranks = mesh.ranks();
    let d = entry.config.d_model;
    let examples_per_rank = entry.config.batch_size / ranks.max(1);
    let net = Interconnect::shared_memory(mesh.ep);
    let mut total_s = 0.0;
    for (tag, spec) in entry.moe_block_tags() {
        let len = if tag.starts_with("enc") { entry.config.enc_len } else { entry.config.dec_len };
        let n_rank = examples_per_rank * len;
        let c = (((n_rank as f64 * spec.capacity_factor) / spec.num_experts as f64).max(1.0)
            as usize)
            .min(n_rank);
        let rows = spec.num_experts * c;
        let bytes_per_peer = rows * d * 4 / mesh.ep.max(1);
        // 4 exchanges per block per rank per step; every rank measures the
        // same rendezvous window, so the aggregate scales by dp·ep.
        total_s += 4.0 * ranks as f64 * net.alltoall_time(bytes_per_peer);
    }
    total_s * 1e9
}

/// Expert-parallel mesh scaling on the reference sparse LM: serial
/// (1-worker, full experts local) vs threaded (sharded experts + real
/// all-to-all), phase attribution, and the measured-vs-model exchange cost.
fn expert_parallel_section(
    manifest: &Manifest,
    runtime: &Runtime,
    target_ms: u64,
    full: bool,
) -> Json {
    println!("== expert parallel: DP×EP mesh scaling ==");
    let name = "lm_tiny_moe_e8_c2";
    let entry = manifest.model(name).unwrap().clone();
    let model = runtime.load_model(manifest, name, &["train", "eval"]).unwrap();
    let mut pipe = pipeline(&entry);
    let batch = pipe.next();
    let tokens = tokens_per_step(&entry);

    // Serial reference before its threaded twin, per mesh shape, so every
    // speedup compares identical shard decompositions.
    let mut plans = vec![(1usize, 2usize, false), (1, 2, true), (2, 2, false), (2, 2, true)];
    if full {
        plans.push((1, 4, false));
        plans.push((1, 4, true));
    }
    let mut entries = Vec::new();
    let mut serial_ns: std::collections::BTreeMap<(usize, usize), f64> =
        std::collections::BTreeMap::new();
    for (dp, ep, parallel) in plans {
        if entry.config.batch_size % (dp * ep) != 0 {
            continue;
        }
        let mesh = MeshConfig { dp, ep, parallel, microbatches: 1 };
        let label = format!(
            "mesh_train_step {name} {dp}x{ep}{}",
            if parallel { "" } else { " [serial ref]" }
        );
        let mut state = fresh_state(&entry);
        let mut step = 0u64;
        let r = bench(&label, target_ms, || {
            step += 1;
            let params = std::mem::take(&mut state.params);
            let opt = std::mem::take(&mut state.opt_state);
            let out = mesh_train_step(&model, params, opt, &batch, 1e-3, 0.0, step, &mesh)
                .unwrap();
            state.params = out.params;
            state.opt_state = out.opt_state;
        });
        if !parallel {
            serial_ns.insert((dp, ep), r.mean_ns);
        }

        // Phase attribution over a few profiled steps (parallel plans only:
        // the serial reference never touches the exchange).
        let mut alltoall_ns = 0.0;
        let mut ep_mlp_ns = 0.0;
        let profiled_steps = 3u64;
        if parallel {
            phases_reset();
            phases_enable(true);
            for i in 1..=profiled_steps {
                let params = std::mem::take(&mut state.params);
                let opt = std::mem::take(&mut state.opt_state);
                let out =
                    mesh_train_step(&model, params, opt, &batch, 1e-3, 0.0, 500 + i, &mesh)
                        .unwrap();
                state.params = out.params;
                state.opt_state = out.opt_state;
            }
            phases_enable(false);
            for (phase, total_ns, _calls) in phases_snapshot() {
                if phase == "ep_alltoall" {
                    alltoall_ns = total_ns / profiled_steps as f64;
                } else if phase == "ep_expert_mlp" {
                    ep_mlp_ns = total_ns / profiled_steps as f64;
                }
            }
            phases_reset();
        }
        let model_ns = alltoall_model_ns_per_step(&entry, &mesh);
        // Speedup vs the serial run of the SAME mesh shape (identical shard
        // decomposition; 0 when no serial reference was measured).
        let speedup = serial_ns.get(&(dp, ep)).map(|s| s / r.mean_ns).unwrap_or(0.0);
        if parallel {
            println!(
                "  ↳ {dp}x{ep}: {:.2}x vs serial mesh, alltoall {:.1} µs/step (model {:.1} µs)",
                speedup,
                alltoall_ns / 1e3,
                model_ns / 1e3
            );
        }
        entries.push(obj(vec![
            ("dp", num(dp as f64)),
            ("ep", num(ep as f64)),
            ("parallel", Json::Bool(parallel)),
            ("mean_ns", num(r.mean_ns)),
            ("p50_ns", num(r.p50_ns)),
            ("steps_per_s", num(1e9 / r.mean_ns)),
            ("tokens_per_s", num(tokens * 1e9 / r.mean_ns)),
            ("speedup_vs_serial_mesh", num(speedup)),
            ("alltoall_ns_per_step", num(alltoall_ns)),
            ("expert_mlp_ns_per_step", num(ep_mlp_ns)),
            ("alltoall_model_ns_per_step", num(model_ns)),
            (
                "alltoall_model_error",
                num(if model_ns > 0.0 && alltoall_ns > 0.0 { alltoall_ns / model_ns } else { 0.0 }),
            ),
        ]));
    }
    obj(vec![
        ("model", s(name)),
        ("tokens_per_step", num(tokens)),
        ("moe_blocks", num(entry.moe_block_tags().len() as f64)),
        ("plans", arr(entries)),
    ])
}

/// Overlap sweep: the same 1×2 mesh step at microbatch counts 1/2/4. The
/// `ep_alltoall` phase only times the *blocking* `finish_exchange` legs of
/// the split-phase pipeline — the exposed communication window — so as the
/// microbatch count grows and microbatch k's exchange rides behind
/// microbatch k−1's expert compute, that window should shrink while the
/// step stays bitwise-identical to the fused (`microbatches = 1`) run.
fn overlap_section(manifest: &Manifest, runtime: &Runtime, target_ms: u64) -> Json {
    println!("== overlap: exposed all-to-all window vs microbatch count ==");
    let name = "lm_tiny_moe_e8_c2";
    let entry = manifest.model(name).unwrap().clone();
    let model = runtime.load_model(manifest, name, &["train", "eval"]).unwrap();
    let mut pipe = pipeline(&entry);
    let batch = pipe.next();
    let tokens = tokens_per_step(&entry);

    let mut entries = Vec::new();
    let mut fused_alltoall_ns = 0.0;
    for m in [1usize, 2, 4] {
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: m };
        let mut state = fresh_state(&entry);
        let mut step = 0u64;
        let r = bench(&format!("mesh_train_step {name} 1x2 mb{m}"), target_ms, || {
            step += 1;
            let params = std::mem::take(&mut state.params);
            let opt = std::mem::take(&mut state.opt_state);
            let out =
                mesh_train_step(&model, params, opt, &batch, 1e-3, 0.0, step, &mesh).unwrap();
            state.params = out.params;
            state.opt_state = out.opt_state;
        });

        // Exposed-window attribution over a few profiled steps.
        let mut alltoall_ns = 0.0;
        let mut ep_mlp_ns = 0.0;
        let profiled_steps = 3u64;
        phases_reset();
        phases_enable(true);
        for i in 1..=profiled_steps {
            let params = std::mem::take(&mut state.params);
            let opt = std::mem::take(&mut state.opt_state);
            let out =
                mesh_train_step(&model, params, opt, &batch, 1e-3, 0.0, 700 + i, &mesh).unwrap();
            state.params = out.params;
            state.opt_state = out.opt_state;
        }
        phases_enable(false);
        for (phase, total_ns, _calls) in phases_snapshot() {
            if phase == "ep_alltoall" {
                alltoall_ns = total_ns / profiled_steps as f64;
            } else if phase == "ep_expert_mlp" {
                ep_mlp_ns = total_ns / profiled_steps as f64;
            }
        }
        phases_reset();
        if m == 1 {
            fused_alltoall_ns = alltoall_ns;
        }
        let hidden = if fused_alltoall_ns > 0.0 {
            1.0 - alltoall_ns / fused_alltoall_ns
        } else {
            0.0
        };
        println!(
            "  ↳ mb={m}: exposed alltoall {:.1} µs/step ({:.0}% hidden vs fused), \
             expert_mlp {:.1} µs/step",
            alltoall_ns / 1e3,
            hidden * 100.0,
            ep_mlp_ns / 1e3
        );
        entries.push(obj(vec![
            ("microbatches", num(m as f64)),
            ("mean_ns", num(r.mean_ns)),
            ("p50_ns", num(r.p50_ns)),
            ("steps_per_s", num(1e9 / r.mean_ns)),
            ("tokens_per_s", num(tokens * 1e9 / r.mean_ns)),
            ("exposed_alltoall_ns_per_step", num(alltoall_ns)),
            ("expert_mlp_ns_per_step", num(ep_mlp_ns)),
            ("hidden_fraction_vs_fused", num(hidden)),
        ]));
    }
    println!();
    obj(vec![
        ("model", s(name)),
        ("mesh", s("dp=1,ep=2")),
        ("tokens_per_step", num(tokens)),
        ("sweep", arr(entries)),
    ])
}

/// Forward-only inference: batch-size sweep of `Executable::infer` plus
/// the serve engine's batched-vs-unbatched comparison on one fixed burst
/// trace (see docs/BENCHMARKS.md §inference for the schema, and
/// docs/SERVING.md for engine semantics).
fn inference_section(manifest: &Manifest, runtime: &Runtime, target_ms: u64) -> Json {
    println!("== inference: forward-only batch sweep + continuous batching ==");
    let name = "lm_tiny_moe_e8_c2";
    let entry = manifest.model(name).unwrap().clone();
    let model = runtime.load_model(manifest, name, &["eval"]).unwrap();
    let state = fresh_state(&entry);
    let params = &state.params;

    // Batch-size sweep: same per-example geometry, growing batch dim.
    let mut sweep = Vec::new();
    let tokens_per_example = (entry.config.enc_len + entry.config.dec_len) as f64;
    for &b in &[1usize, 2, 4, 8] {
        if b > entry.config.batch_size {
            continue;
        }
        let trace = serve::synthetic_trace(&entry, b, 3, 0);
        let inputs = serve::stack_inputs(&trace).unwrap();
        let r = bench(&format!("infer {name} b{b}"), target_ms, || {
            std::hint::black_box(model.infer(params, &inputs).unwrap());
        });
        let toks = tokens_per_example * b as f64;
        println!(
            "  ↳ b={b}: {:.1} inferences/s, {:.1} tokens/s",
            1e9 / r.mean_ns,
            toks * 1e9 / r.mean_ns
        );
        sweep.push(obj(vec![
            ("batch", num(b as f64)),
            ("mean_ns", num(r.mean_ns)),
            ("p50_ns", num(r.p50_ns)),
            ("p99_ns", num(r.p99_ns)),
            ("tokens_per_s", num(toks * 1e9 / r.mean_ns)),
        ]));
    }

    // Continuous batching vs one-request-per-batch serving on the SAME
    // burst trace (identical requests, identical arrival times). One
    // warmup run per config, then the measured run.
    let n_req = 16usize;
    let tpr = serve::tokens_per_request(&entry);
    let run = |spec: serve::ServeSpec| {
        let engine = serve::Engine::new(&model, params, spec).unwrap();
        engine.run_trace(serve::synthetic_trace(&entry, n_req, 9, 0)).unwrap();
        engine.run_trace(serve::synthetic_trace(&entry, n_req, 9, 0)).unwrap()
    };
    let batched = run(serve::ServeSpec { max_batch_tokens: 8 * tpr, ..Default::default() });
    let unbatched = run(serve::ServeSpec::unbatched());
    let speedup = batched.tokens_per_s() / unbatched.tokens_per_s().max(1e-9);
    println!(
        "  ↳ engine, {n_req}-request burst: batched {:.1} tokens/s in {} micro-batch(es) vs \
         unbatched {:.1} tokens/s — {speedup:.2}x\n",
        batched.tokens_per_s(),
        batched.batches.len(),
        unbatched.tokens_per_s()
    );
    let engine_json = |r: &serve::ServeReport| {
        obj(vec![
            ("micro_batches", num(r.batches.len() as f64)),
            ("total_tokens", num(r.total_tokens() as f64)),
            ("exec_wall_ns", num(r.exec_wall_ns())),
            ("tokens_per_s", num(r.tokens_per_s())),
            ("p50_latency_us", num(r.p50_latency_us())),
            ("p99_latency_us", num(r.p99_latency_us())),
        ])
    };
    obj(vec![
        ("model", s(name)),
        ("tokens_per_request", num(tpr as f64)),
        ("batch_sweep", arr(sweep)),
        ("engine_requests", num(n_req as f64)),
        ("engine_batched", engine_json(&batched)),
        ("engine_unbatched", engine_json(&unbatched)),
        ("batched_speedup", num(speedup)),
    ])
}

/// Heavy-traffic serving: one bursty multi-tenant trace replayed through
/// every scheduler policy under the same bounded queue, recording virtual
/// tail latency (p99 + interpolated p999), shed rate, and per-tenant
/// goodput (policy semantics: docs/SERVING.md; schema: docs/BENCHMARKS.md
/// §serving_load). Everything except `tokens_per_s` lives on the virtual
/// clock, so these numbers are a pure function of (trace, ServeSpec).
fn serving_load_section(manifest: &Manifest, runtime: &Runtime) -> Json {
    println!("== serving load: scheduler policies under bursty multi-tenant traffic ==");
    let name = "lm_tiny_moe_e8_c2";
    let entry = manifest.model(name).unwrap().clone();
    let model = runtime.load_model(manifest, name, &["eval"]).unwrap();
    let state = fresh_state(&entry);
    let params = &state.params;

    let n_req = 48usize;
    let tenants = 4usize;
    let queue = 8usize;
    let tpr = serve::tokens_per_request(&entry);
    let process = serve::ArrivalProcess::Bursty { mean_gap_us: 100, burst: 8 };
    let trace =
        serve::generate(&entry, &serve::TrafficSpec::standard(process, tenants, n_req, 11))
            .unwrap();

    let mut policies = Vec::new();
    for kind in [
        serve::PolicyKind::Fifo,
        serve::PolicyKind::Priority,
        serve::PolicyKind::FairShare,
        serve::PolicyKind::SloDeadline,
    ] {
        let spec = serve::ServeSpec {
            policy: kind,
            max_batch_tokens: 4 * tpr,
            queue_capacity: queue,
            priority_floor_us: if kind == serve::PolicyKind::Priority { 10_000 } else { 0 },
            slo_default_us: if kind == serve::PolicyKind::SloDeadline { 20_000 } else { 0 },
            ..Default::default()
        };
        let engine = serve::Engine::new(&model, params, spec).unwrap();
        // One warmup run for stable wall-time throughput; the virtual-clock
        // numbers are bitwise-identical between the two runs.
        engine.run_trace(trace.clone()).unwrap();
        let report = engine.run_trace(trace.clone()).unwrap();

        // Goodput denominator: virtual makespan (last micro-batch finish).
        let makespan_us = report.batches.iter().map(|b| b.finish_us).max().unwrap_or(0);
        let goodput: Vec<Json> = report
            .tenant_counts()
            .into_iter()
            .map(|(tenant, done, shed)| {
                let tokens = (done * tpr) as f64;
                let per_vs =
                    if makespan_us > 0 { tokens * 1e6 / makespan_us as f64 } else { 0.0 };
                obj(vec![
                    ("tenant", num(tenant as f64)),
                    ("completed", num(done as f64)),
                    ("shed", num(shed as f64)),
                    ("goodput_tokens_per_vs", num(per_vs)),
                ])
            })
            .collect();
        println!(
            "  ↳ {}: {} completed, {} shed ({:.1}%), p99 {:.0} µs, p999 {:.0} µs",
            kind.name(),
            report.completions.len(),
            report.sheds.len(),
            report.shed_rate() * 100.0,
            report.p99_latency_us(),
            report.p999_latency_us()
        );
        policies.push(obj(vec![
            ("policy", s(kind.name())),
            ("completed", num(report.completions.len() as f64)),
            ("shed", num(report.sheds.len() as f64)),
            ("shed_rate", num(report.shed_rate())),
            ("micro_batches", num(report.batches.len() as f64)),
            ("p50_latency_us", num(report.p50_latency_us())),
            ("p99_latency_us", num(report.p99_latency_us())),
            ("p999_latency_us", num(report.p999_latency_us())),
            ("virtual_makespan_us", num(makespan_us as f64)),
            ("tokens_per_s", num(report.tokens_per_s())),
            ("tenant_goodput", arr(goodput)),
        ]));
    }
    println!();
    obj(vec![
        ("model", s(name)),
        ("requests", num(n_req as f64)),
        ("tenants", num(tenants as f64)),
        ("arrival_process", s(process.name())),
        ("queue_capacity", num(queue as f64)),
        ("tokens_per_request", num(tpr as f64)),
        ("policies", arr(policies)),
    ])
}

/// The sweep lab's *planning* path (spec parse → leg enumeration → cost
/// pricing → LPT packing) and the power-law fitter — the pure-CPU overhead
/// the scheduler wraps around training (docs/SWEEPS.md). No legs train
/// here; the point is that planning a 24-leg grid is microseconds, so the
/// sweep harness adds nothing measurable to a run.
fn sweep_section(manifest: &Manifest, target_ms: u64) -> Json {
    println!("== sweep lab: plan + fit overhead ==");
    let text = "sunk=30+60,experts=2+8+16,capacity=2,strategy=replicate+drop,\
                reinit=0.25,budget=20+40";
    let cores = 4usize;
    let spec = sweep::SweepSpec::parse(text).unwrap();
    let legs = spec.legs(manifest, 17).unwrap();
    let r_plan = bench("sweep plan (parse+legs+price+pack)", target_ms, || {
        let spec = sweep::SweepSpec::parse(text).unwrap();
        let legs = spec.legs(manifest, 17).unwrap();
        let priced = sweep::price_legs(manifest, &legs).unwrap();
        std::hint::black_box(sweep::pack(&priced, cores));
    });
    println!("  ↳ {:.1} µs per {}-leg plan", r_plan.mean_ns / 1e3, legs.len());

    let priced = sweep::price_legs(manifest, &legs).unwrap();
    let packing = sweep::pack(&priced, cores);
    // LPT balance: heaviest bin over the perfectly-even share (1.0 = ideal).
    let balance = packing.makespan_flops / (packing.total_flops / cores as f64);
    println!("  ↳ packed onto {cores} cores, makespan/ideal = {balance:.3}");

    // Fitter on a synthetic exact power law over this grid's priced axes.
    let points: Vec<sweep::fit::FitPoint> = legs
        .iter()
        .zip(&priced)
        .map(|(leg, p)| sweep::fit::FitPoint {
            label: leg.label(),
            loss: 3.0
                * p.sunk.flops.powf(-0.1)
                * (leg.experts as f64).powf(-0.05)
                * p.extra.flops.powf(-0.2),
            regressors: [p.sunk.flops, leg.experts as f64, p.extra.flops],
        })
        .collect();
    let r_fit = bench("sweep power-law fit", target_ms, || {
        std::hint::black_box(sweep::fit::power_law_fit(&points).unwrap());
    });
    println!("  ↳ {:.1} µs per {}-point fit\n", r_fit.mean_ns / 1e3, points.len());

    obj(vec![
        ("spec", s(text)),
        ("grid_legs", num(legs.len() as f64)),
        ("cores", num(cores as f64)),
        ("makespan_over_ideal", num(balance)),
        ("plan", result_json(&r_plan, legs.len() as f64, 0.0)),
        ("fit", result_json(&r_fit, points.len() as f64, 0.0)),
    ])
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let quick = args.iter().any(|a| a == "--quick");
    let json_out = args
        .iter()
        .position(|a| a == "--json-out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_runtime.json".to_string());

    let manifest = match Manifest::load_or_native("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench (bad artifacts): {e}");
            return;
        }
    };
    let runtime = Runtime::for_manifest(&manifest).unwrap();
    let reference_backend = NativeBackend::reference_kernels();
    println!("platform: {}  (manifest source: {})", runtime.platform(), manifest.source_hash);

    let (t_train, t_eval, t_kern) = if quick { (300, 200, 100) } else { (1500, 800, 300) };

    let variants: &[&str] = if full {
        &[
            "lm_tiny_dense",
            "lm_tiny_moe_e8_c1",
            "lm_tiny_moe_e8_c2",
            "lm_tiny_moe_e8_c3",
            "lm_tiny_moe_e2_c2",
            "lm_tiny_moe_e16_c2",
            "vit_tiny_dense",
            "vit_tiny_moe_e8_c2",
        ]
    } else {
        &["lm_tiny_dense", "lm_tiny_moe_e8_c1", "lm_tiny_moe_e8_c2", "vit_tiny_moe_e8_c2"]
    };

    let kernels = kernel_section(t_kern);
    let simd_kernels = simd_section(t_kern);
    let expert_parallel = expert_parallel_section(&manifest, &runtime, t_eval, full);
    let overlap = overlap_section(&manifest, &runtime, t_eval);
    let inference = inference_section(&manifest, &runtime, t_eval);
    let quantized_inference = quantized_inference_section(&manifest, t_eval);
    let serving_load = serving_load_section(&manifest, &runtime);
    let sweep_lab = sweep_section(&manifest, t_kern);

    let mut model_entries = Vec::new();
    for name in variants {
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["train", "eval"]).unwrap();
        let scalar = reference_backend.load_model(&manifest, name, &["train", "eval"]).unwrap();
        let mut state = fresh_state(&entry);
        let mut pipe = pipeline(&entry);
        let batch = pipe.next();
        let tokens = tokens_per_step(&entry);
        let flops = entry.flops.train_step;

        // Blocked-kernel step (the shipping path).
        let r_train =
            bench_train(&format!("train_step {name}"), &model, &mut state, &batch, t_train);
        println!(
            "  ↳ {:.1} steps/s, {:.1} tokens/s, {:.2} GFLOP/s achieved",
            1e9 / r_train.mean_ns,
            tokens * 1e9 / r_train.mean_ns,
            flops / r_train.mean_ns
        );

        // The preserved PR 1 scalar path, same model + batch.
        let mut ref_state = fresh_state(&entry);
        let r_ref = bench_train(
            &format!("train_step {name} [scalar ref]"),
            &scalar,
            &mut ref_state,
            &batch,
            t_eval,
        );
        let step_speedup = r_ref.mean_ns / r_train.mean_ns;
        println!("  ↳ blocked vs PR 1 scalar: {step_speedup:.2}x");

        let r_eval = bench(&format!("eval_step  {name}"), t_eval, || {
            std::hint::black_box(model.eval_step(&state.params, &batch).unwrap());
        });
        println!("  ↳ {:.1} evals/s", 1e9 / r_eval.mean_ns);

        // Per-phase attribution over a few profiled steps.
        phases_reset();
        phases_enable(true);
        let profiled_steps = 5u64;
        let wall = std::time::Instant::now();
        for i in 1..=profiled_steps {
            let params = std::mem::take(&mut state.params);
            let opt = std::mem::take(&mut state.opt_state);
            let out = model.train_step(params, opt, &batch, 1e-3, 0.0, 1000 + i).unwrap();
            state.params = out.params;
            state.opt_state = out.opt_state;
        }
        let wall_ns = wall.elapsed().as_nanos() as f64;
        phases_enable(false);
        let mut phases = Vec::new();
        let mut attributed = 0.0;
        for (phase, total_ns, calls) in phases_snapshot() {
            attributed += total_ns;
            phases.push(obj(vec![
                ("phase", s(&phase)),
                ("total_ns", num(total_ns)),
                ("calls", num(calls as f64)),
                ("fraction_of_step", num(total_ns / wall_ns)),
            ]));
        }
        phases.push(obj(vec![
            ("phase", s("other")),
            ("total_ns", num((wall_ns - attributed).max(0.0))),
            ("calls", num(profiled_steps as f64)),
            ("fraction_of_step", num(((wall_ns - attributed) / wall_ns).max(0.0))),
        ]));

        // Data-parallel scaling: same shard decomposition, 1 vs N workers.
        let mut dp_entries = Vec::new();
        let mut best_dp_ns = r_train.mean_ns;
        let mut dp_plans = vec![(2usize, 1usize), (2, 2)];
        if full {
            dp_plans.push((4, 4));
        }
        for (replicas, workers) in dp_plans {
            if entry.config.batch_size % replicas != 0 {
                continue;
            }
            let dp = DpConfig { replicas, workers };
            let mut dp_state = fresh_state(&entry);
            let mut step = 0u64;
            let r_dp = bench(
                &format!("dp_train_step {name} r{replicas} w{workers}"),
                t_eval,
                || {
                    step += 1;
                    let params = std::mem::take(&mut dp_state.params);
                    let opt = std::mem::take(&mut dp_state.opt_state);
                    let out =
                        dp_train_step(&model, params, opt, &batch, 1e-3, 0.0, step, &dp).unwrap();
                    dp_state.params = out.params;
                    dp_state.opt_state = out.opt_state;
                },
            );
            if workers > 1 {
                best_dp_ns = best_dp_ns.min(r_dp.mean_ns);
            }
            dp_entries.push(obj(vec![
                ("replicas", num(replicas as f64)),
                ("workers", num(workers as f64)),
                ("mean_ns", num(r_dp.mean_ns)),
                ("steps_per_s", num(1e9 / r_dp.mean_ns)),
                ("tokens_per_s", num(tokens * 1e9 / r_dp.mean_ns)),
            ]));
        }
        println!("  ↳ best step vs PR 1 scalar: {:.2}x\n", r_ref.mean_ns / best_dp_ns);

        model_entries.push(obj(vec![
            ("model", s(name)),
            ("family", s(&entry.family)),
            ("sparse", Json::Bool(entry.is_sparse())),
            ("tokens_per_step", num(tokens)),
            ("analytic_train_mflop", num(flops / 1e6)),
            ("train", result_json(&r_train, tokens, flops)),
            ("train_reference_scalar", result_json(&r_ref, tokens, flops)),
            ("step_speedup_vs_scalar", num(step_speedup)),
            ("best_speedup_vs_scalar", num(r_ref.mean_ns / best_dp_ns)),
            ("eval", result_json(&r_eval, tokens, entry.flops.eval_step)),
            ("phases", arr(phases)),
            ("data_parallel", arr(dp_entries)),
        ]));
    }

    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let report = obj(vec![
        ("schema_version", num(1.0)),
        ("bench", s("runtime_step")),
        ("platform", s(&runtime.platform())),
        ("manifest_source", s(&manifest.source_hash)),
        ("threads", num(threads as f64)),
        ("unix_time_s", num(unix_s as f64)),
        ("quick", Json::Bool(quick)),
        ("full", Json::Bool(full)),
        ("kernels", kernels),
        ("simd", simd_kernels),
        ("expert_parallel", expert_parallel),
        ("overlap", overlap),
        ("inference", inference),
        ("quantized_inference", quantized_inference),
        ("serving_load", serving_load),
        ("sweep", sweep_lab),
        ("models", arr(model_entries)),
    ]);
    std::fs::write(&json_out, report.to_string()).expect("writing bench JSON");
    println!("wrote {json_out}");
}
