//! Bench: end-to-end training/eval step cost through the execution backend —
//! the L3 hot path. This regenerates the paper's per-step cost claims:
//!
//! * Fig. 9 / §2.1: a sparse step costs ≈ C× the dense MLP FLOPs + router,
//!   so dense < C=1 < C=2 < C=3;
//! * §3.1 "number of experts": E is ~FLOPs-neutral (E=2 vs E=16 ≈ same);
//!
//! and it is the measurement harness for the §Perf optimization loop:
//! native step latency, steps/s and achieved FLOP/s per variant. Runs on
//! the native CPU backend out of the box (no artifacts needed); a `pjrt`
//! build with `artifacts/manifest.json` present measures the AOT
//! signatures instead.
//!
//! Run: cargo bench --bench runtime_step [-- --full]

use sparse_upcycle::coordinator::TrainState;
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::runtime::Runtime;
use sparse_upcycle::util::bench::bench;

fn main() {
    let manifest = match Manifest::load_or_native("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench (bad artifacts): {e}");
            return;
        }
    };
    let runtime = Runtime::for_manifest(&manifest).unwrap();
    println!("platform: {}  (manifest source: {})", runtime.platform(), manifest.source_hash);

    // Pass --full for the whole C/E sweep.
    let full = std::env::args().any(|a| a == "--full");
    let variants: &[&str] = if full {
        &[
            "lm_tiny_dense",
            "lm_tiny_moe_e8_c1",
            "lm_tiny_moe_e8_c2",
            "lm_tiny_moe_e8_c3",
            "lm_tiny_moe_e2_c2",
            "lm_tiny_moe_e16_c2",
            "vit_tiny_dense",
            "vit_tiny_moe_e8_c2",
        ]
    } else {
        &["lm_tiny_dense", "lm_tiny_moe_e8_c1", "lm_tiny_moe_e8_c2", "vit_tiny_moe_e8_c2"]
    };

    for name in variants {
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["train", "eval"]).unwrap();
        let mut state = TrainState::from_checkpoints(
            &entry,
            &init_params(&entry, 0).unwrap(),
            &init_opt_state(&entry).unwrap(),
        )
        .unwrap();
        let mut pipeline: Box<dyn sparse_upcycle::coordinator::BatchSource> =
            if entry.family == "lm" {
                Box::new(sparse_upcycle::data::text::TextPipeline::new(
                    sparse_upcycle::data::text::HmmCorpus::new(
                        sparse_upcycle::data::text::HmmSpec {
                            vocab_size: entry.config.vocab_size,
                            ..Default::default()
                        },
                        1,
                    ),
                    entry.config.batch_size,
                    entry.config.enc_len,
                    entry.config.dec_len,
                    1,
                    0,
                ))
            } else {
                Box::new(sparse_upcycle::data::vision::VisionPipeline::new(
                    sparse_upcycle::data::vision::VisionSpec::default(),
                    entry.config.batch_size,
                    1,
                    0,
                ))
            };
        let batch = pipeline.next();
        let mut step = 0u64;
        let r = bench(&format!("train_step {name}"), 1500, || {
            step += 1;
            let params = std::mem::take(&mut state.params);
            let opt = std::mem::take(&mut state.opt_state);
            let out = model.train_step(params, opt, &batch, 1e-3, 0.0, step).unwrap();
            state.params = out.params;
            state.opt_state = out.opt_state;
        });
        let flops = entry.flops.train_step;
        println!(
            "  ↳ {:.1} steps/s, {:.2} GFLOP/s achieved (analytic {:.2} MFLOP/step)",
            1e9 / r.mean_ns,
            flops / r.mean_ns,
            flops / 1e6
        );
        let r = bench(&format!("eval_step  {name}"), 800, || {
            std::hint::black_box(model.eval_step(&state.params, &batch).unwrap());
        });
        println!("  ↳ {:.1} evals/s\n", 1e9 / r.mean_ns);
    }
}
