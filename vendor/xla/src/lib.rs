//! API-compatible **stub** of the `xla` / PJRT Rust bindings.
//!
//! The real PJRT bindings link against libxla, which cannot be built in this
//! offline container. This stub exposes the exact API surface the `pjrt`
//! cargo feature of `sparse-upcycle` compiles against, so the feature-gated
//! code keeps type-checking in CI. Host-side [`Literal`] operations are
//! implemented for real (they are plain memory); every device operation
//! (client construction, compilation, execution) returns
//! [`Error::Unavailable`] at runtime.
//!
//! To run the PJRT backend for real, replace this path dependency with the
//! actual `xla` crate in the workspace `Cargo.toml`.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub enum Error {
    /// The stub cannot perform device operations.
    Unavailable(String),
    /// Host-side literal misuse (wrong dtype, bad reshape, ...).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT is unavailable in this build (vendor/xla is a stub; \
                 link the real xla crate to enable the `pjrt` backend)"
            ),
            Error::Literal(m) => write!(f, "literal error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side typed element: the types `Literal` can hold.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn push_into(data: &[Self], lit: &mut LiteralData);
    fn extract(lit: &LiteralData) -> Option<Vec<Self>>;
}

#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn push_into(data: &[Self], lit: &mut LiteralData) {
        *lit = LiteralData::F32(data.to_vec());
    }

    fn extract(lit: &LiteralData) -> Option<Vec<Self>> {
        match lit {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn push_into(data: &[Self], lit: &mut LiteralData) {
        *lit = LiteralData::I32(data.to_vec());
    }

    fn extract(lit: &LiteralData) -> Option<Vec<Self>> {
        match lit {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: dims + typed buffer. Fully functional (host memory only).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut d = LiteralData::F32(Vec::new());
        T::push_into(data, &mut d);
        Literal { dims: vec![data.len() as i64], data: d }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = match &self.data {
            LiteralData::F32(v) => v.len() as i64,
            LiteralData::I32(v) => v.len() as i64,
        };
        if n != have {
            return Err(Error::Literal(format!("cannot reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data).ok_or_else(|| Error::Literal("dtype mismatch".to_string()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("decomposing a device tuple literal")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        unavailable("parsing HLO text")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating a PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an XLA computation")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a PJRT executable")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("syncing a device buffer to host")
    }
}
