//! Minimal, offline-vendored subset of the `anyhow` error-handling API.
//!
//! This container has no crates.io access, so the workspace vendors the
//! small slice of `anyhow` the codebase actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!`
//! / `ensure!` macros. Semantics follow upstream where it matters:
//!
//! * `Display` shows the outermost message; `{:#}` shows the whole context
//!   chain joined by `": "` (the `eprintln!("{e:#}")` convention).
//! * Any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//!   via `?`. [`Error`] itself deliberately does **not** implement
//!   `std::error::Error`, exactly like upstream, so the blanket conversion
//!   does not conflict with the identity `From`.
//! * `Context` is implemented for both `Result` and `Option`.

use std::fmt;

/// Error type: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.chain.len() == 1 {
            return f.write_str(&self.chain[0]);
        }
        writeln!(f, "{}", self.chain[0])?;
        writeln!(f, "\nCaused by:")?;
        for (i, c) in self.chain.iter().skip(1).enumerate() {
            writeln!(f, "    {i}: {c}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context frames.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`; the error defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn conversion_and_context() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(format!("{e}"), "missing flag");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "x too large: 200");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), std::num::ParseIntError> = "x".parse::<i32>().map(|_| ());
        let e = r.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "parsing x: invalid digit found in string");
    }
}
