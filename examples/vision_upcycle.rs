//! Vision upcycling scenario (paper §4.1 vision setup): pretrain a tiny ViT
//! on the procedural shapes dataset, upcycle it into a V-MoE-style model
//! with Expert Choice routing + combine-weight renormalization + resumed
//! optimizer state (the paper's vision-specific recipe), then report the
//! 10-shot linear probe (§A.2.2) alongside validation accuracy.
//!
//! Run: cargo run --release --example vision_upcycle

use anyhow::Result;

use sparse_upcycle::coordinator::fewshot::{fewshot_accuracy, FewShotConfig};
use sparse_upcycle::experiments::{Ctx, ExpParams};
use sparse_upcycle::upcycle::UpcycleOptions;
use sparse_upcycle::util::cli::Args;

fn main() -> Result<()> {
    let a = Args::parse(std::env::args().skip(1))?;
    let mut p = ExpParams::tiny();
    p.pretrain_steps = a.u64("pretrain-steps", 300)?;
    p.extra_steps = a.u64("extra-steps", 180)?;
    let ctx = Ctx::new("artifacts", "results/vision", p, true)?;

    println!("== vision sparse upcycling (ViT -> V-MoE, Expert Choice) ==");
    let parent = ctx.dense_parent("vit_tiny_dense", ctx.p.pretrain_steps)?;

    // Vision recipe (§3.1): resume optimizer state + renormalized combine
    // weights (the vit_tiny_moe_e8_c2 artifact has renormalize=true).
    let (moe_model, mut moe_state) =
        ctx.branch_upcycle(&parent, "vit_tiny_moe_e8_c2", &UpcycleOptions::default(), true)?;
    let (dense_model, mut dense_state) = ctx.branch_dense(&parent, "vit_tiny_dense")?;

    let dense_series =
        ctx.run_branch(&dense_model, &mut dense_state, 1, ctx.p.extra_steps, "dense")?;
    let moe_series = ctx.run_branch(&moe_model, &mut moe_state, 2, ctx.p.extra_steps, "upcycled")?;

    // 10-shot linear probes on frozen features (5 support seeds).
    let moe_feats = ctx.load("vit_tiny_moe_e8_c2", &["features"])?;
    let dense_feats = ctx.load("vit_tiny_dense", &["features"])?;
    let cfg = FewShotConfig::default();
    let moe_10shot = fewshot_accuracy(&moe_feats, &moe_state.params, &cfg, ctx.p.seed)?;
    let dense_10shot = fewshot_accuracy(&dense_feats, &dense_state.params, &cfg, ctx.p.seed)?;

    let get = |s: &sparse_upcycle::metrics::Series, k: &str| {
        s.last().and_then(|pt| pt.values.get(k).copied()).unwrap_or(f64::NAN)
    };
    println!("\n== results after +{} steps ==", ctx.p.extra_steps);
    println!("  {:<20} {:>10} {:>10}", "branch", "val-acc", "10-shot");
    println!("  {:<20} {:>10.4} {:>10.4}", "dense continuation",
             get(&dense_series, "accuracy"), dense_10shot);
    println!("  {:<20} {:>10.4} {:>10.4}", "upcycled V-MoE",
             get(&moe_series, "accuracy"), moe_10shot);
    Ok(())
}
