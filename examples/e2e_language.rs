//! End-to-end driver (DESIGN.md §5): the full system on a real workload.
//!
//! Pipeline — all three layers composing:
//!   synthetic C4 corpus (L3 data) → span corruption (L3) → AOT train step
//!   (L2 jax model calling L1 Pallas kernels, compiled via PJRT) → Adafactor
//!   updates (inside the step) → dense checkpoint (L3) → **upcycling surgery**
//!   (L3, the paper's algorithm) → continued MoE training → downstream
//!   finetuning → headline comparison + loss curves logged to CSV.
//!
//! Default scale is `small` (dense ≈ 11.7M params → upcycled sparse ≈ 34M);
//! `--scale tiny` runs in under a minute. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: cargo run --release --example e2e_language -- [--scale small|tiny]
//!       [--pretrain-steps N] [--extra-steps N]

use anyhow::Result;

use sparse_upcycle::experiments::{Ctx, ExpParams};
use sparse_upcycle::metrics::Report;
use sparse_upcycle::upcycle::UpcycleOptions;
use sparse_upcycle::util::cli::Args;

fn main() -> Result<()> {
    let a = Args::parse(std::env::args().skip(1))?;
    let scale = a.str("scale", "small");
    let (dense_name, sparse_name) = match scale.as_str() {
        "small" => ("lm_small_dense", "lm_small_moe_e8_c2"),
        "tiny" => ("lm_tiny_dense", "lm_tiny_moe_e8_c2"),
        s => anyhow::bail!("unknown scale `{s}` (small|tiny)"),
    };
    let mut p = ExpParams::tiny();
    p.pretrain_steps = a.u64("pretrain-steps", if scale == "small" { 300 } else { 400 })?;
    p.extra_steps = a.u64("extra-steps", if scale == "small" { 150 } else { 240 })?;
    p.eval_every = a.u64("eval-every", 50)?;
    p.finetune_steps = a.u64("finetune-steps", 80)?;
    let ctx = Ctx::new(
        &a.str("artifacts", "artifacts"),
        &a.str("out", "results/e2e"),
        p,
        true,
    )?;

    let dense_entry = ctx.entry(dense_name)?.clone();
    let sparse_entry = ctx.entry(sparse_name)?.clone();
    println!("== e2e sparse upcycling @ scale `{scale}` ==");
    println!(
        "  dense parent : {dense_name} ({:.2}M params)",
        dense_entry.param_count as f64 / 1e6
    );
    println!(
        "  sparse target: {sparse_name} ({:.2}M params, {:.2}M in experts)",
        sparse_entry.param_count as f64 / 1e6,
        sparse_entry.expert_param_count() as f64 / 1e6
    );

    // 1. Dense pretraining (cached across runs).
    let t0 = std::time::Instant::now();
    let parent = ctx.dense_parent(dense_name, ctx.p.pretrain_steps)?;
    let elapsed = t0.elapsed().as_secs_f64();
    println!("  [t+{elapsed:.0}s] dense parent ready (step {})", parent.0.step);

    let mut report = Report::new("e2e_language", "End-to-end sparse upcycling run");

    // 2a. Dense continuation branch.
    let (dense_model, mut dense_state) = ctx.branch_dense(&parent, dense_name)?;
    let dense_series =
        ctx.run_branch(&dense_model, &mut dense_state, 1, ctx.p.extra_steps, "dense_continuation")?;
    println!("  [t+{:.0}s] dense continuation done", t0.elapsed().as_secs_f64());

    // 2b. Upcycled branch (paper Figure 1 surgery).
    let (moe_model, mut moe_state) =
        ctx.branch_upcycle(&parent, sparse_name, &UpcycleOptions::default(), false)?;
    let moe_series =
        ctx.run_branch(&moe_model, &mut moe_state, 2, ctx.p.extra_steps, "upcycled")?;
    println!("  [t+{:.0}s] upcycled branch done", t0.elapsed().as_secs_f64());

    // 3. Downstream finetuning of both final models.
    let dense_ft = ctx.finetune_accuracy(&dense_model, &mut dense_state, 1e-3)?;
    let moe_ft = ctx.finetune_accuracy(&moe_model, &mut moe_state, 1e-3)?;
    println!("  [t+{:.0}s] finetuning done", t0.elapsed().as_secs_f64());

    // 4. Headline comparison.
    let get = |s: &sparse_upcycle::metrics::Series, k: &str| {
        s.last().and_then(|pt| pt.values.get(k).copied()).unwrap_or(f64::NAN)
    };
    let sunk = sparse_upcycle::costmodel::Cost::of_steps(&dense_entry, ctx.p.pretrain_steps);
    let extra_up = sparse_upcycle::coordinator::trainer::final_cost(&moe_series);
    println!("\n== headline ==");
    println!("  sunk dense cost: {:.4} sim-TPU-core-days", sunk.core_days());
    println!(
        "  upcycling extra: {:.4} sim-TPU-core-days ({:.0}% of sunk)",
        extra_up.core_days(),
        extra_up.relative_pct(&sunk)
    );
    println!("  {:<22} {:>10} {:>12} {:>14}", "branch", "loss", "token-acc", "downstream-acc");
    println!(
        "  {:<22} {:>10.4} {:>12.4} {:>14.4}",
        "dense continuation",
        get(&dense_series, "loss"),
        get(&dense_series, "accuracy"),
        dense_ft
    );
    println!(
        "  {:<22} {:>10.4} {:>12.4} {:>14.4}",
        "upcycled MoE",
        get(&moe_series, "loss"),
        get(&moe_series, "accuracy"),
        moe_ft
    );

    report.add(dense_series);
    report.add(moe_series);
    report.note(format!("scale={scale} dense={dense_name} sparse={sparse_name}"));
    report.note(format!("downstream: dense {dense_ft:.4} vs upcycled {moe_ft:.4}"));
    let csv = report.write_csv(&ctx.out_dir)?;
    report.write_json(&ctx.out_dir)?;
    println!("\nloss curves -> {}", csv.display());
    Ok(())
}
