//! Surgery-design sweep — the paper's §3.1 design decisions explored through
//! the *checkpoint surgery* alone (no training): how router init scale,
//! expert noise, random-vs-copied experts and capacity factor change the
//! model's quality at step 0 relative to its dense parent.
//!
//! This is the cheapest way to see Appendix B.8's message: with combine-
//! weight renormalization and enough capacity, the upcycled model starts
//! exactly where the dense model left off.
//!
//! Run: cargo run --release --example ablation_sweep

use anyhow::Result;

use sparse_upcycle::experiments::{Ctx, ExpParams};
use sparse_upcycle::upcycle::UpcycleOptions;

fn main() -> Result<()> {
    let mut p = ExpParams::tiny();
    p.pretrain_steps = 200;
    let ctx = Ctx::new("artifacts", "results/ablation_sweep", p, false)?;
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;

    // Dense reference.
    let (dense_model, dense_state) = ctx.branch_dense(&parent, "lm_tiny_dense")?;
    let dense_m = ctx.evaluator(&dense_model.entry).eval(&dense_model, &dense_state)?;
    println!("dense parent: loss {:.4} acc {:.4}\n",
             dense_m["loss"], dense_m["accuracy"]);
    println!("{:<46} {:>9} {:>9} {:>9}", "surgery variant", "loss", "acc", "cover");

    let mut eval_variant = |label: &str, target: &str, opts: &UpcycleOptions| -> Result<()> {
        let (model, state) =
            ctx.branch_upcycle_kinds(&parent, target, opts, false, &["eval"])?;
        let m = ctx.evaluator(&model.entry).eval(&model, &state)?;
        println!("{:<46} {:>9.4} {:>9.4} {:>9.3}",
                 label, m["loss"], m["accuracy"], m["coverage"]);
        Ok(())
    };

    for (label, target) in [
        ("standard recipe, C=1", "lm_tiny_moe_e8_c1"),
        ("standard recipe, C=2", "lm_tiny_moe_e8_c2"),
        ("standard recipe, C=3", "lm_tiny_moe_e8_c3"),
        ("standard recipe, C=2 + renormalized weights", "lm_tiny_moe_e8_c2_renorm"),
    ] {
        eval_variant(label, target, &UpcycleOptions::default())?;
    }
    for noise in [0.01f32, 0.05, 0.2] {
        eval_variant(
            &format!("expert noise σ={noise} (B.9)"),
            "lm_tiny_moe_e8_c2",
            &UpcycleOptions { expert_noise: noise, ..Default::default() },
        )?;
    }
    eval_variant(
        "random experts (B.5)",
        "lm_tiny_moe_e8_c2",
        &UpcycleOptions { load_experts: false, ..Default::default() },
    )?;
    for stddev in [0.002f32, 0.02, 0.2] {
        eval_variant(
            &format!("router init σ={stddev}"),
            "lm_tiny_moe_e8_c2",
            &UpcycleOptions { router_stddev: stddev, ..Default::default() },
        )?;
    }
    println!("\npaper shape: loss(step 0) decreases with C; renorm + high C ≈ dense; \
              large noise / random experts / large router init all hurt the start");
    Ok(())
}
