//! Quickstart: the paper's recipe in ~60 lines.
//!
//! 1. Pretrain a tiny dense T5-style LM on the synthetic corpus.
//! 2. Upcycle the checkpoint into an 8-expert MoE (Figure 1 surgery).
//! 3. Continue training both branches with the *same, continued* LR
//!    schedule and compare.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use sparse_upcycle::experiments::{Ctx, ExpParams};
use sparse_upcycle::upcycle::UpcycleOptions;

fn main() -> Result<()> {
    let mut p = ExpParams::tiny();
    p.pretrain_steps = 200;
    p.extra_steps = 120;
    p.eval_every = 40;
    let ctx = Ctx::new("artifacts", "results/quickstart", p, true)?;

    println!("== 1. dense pretraining (the sunk cost) ==");
    let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;

    println!("\n== 2. checkpoint surgery: dense -> 8-expert MoE ==");
    let (moe_model, mut moe_state) =
        ctx.branch_upcycle(&parent, "lm_tiny_moe_e8_c2", &UpcycleOptions::default(), false)?;
    println!(
        "  {} ({:.2}M params) -> {} ({:.2}M params)",
        parent.0.model,
        ctx.entry("lm_tiny_dense")?.param_count as f64 / 1e6,
        moe_model.entry.name,
        moe_model.entry.param_count as f64 / 1e6,
    );

    println!("\n== 3. continue both branches ==");
    let (dense_model, mut dense_state) = ctx.branch_dense(&parent, "lm_tiny_dense")?;
    let dense_series =
        ctx.run_branch(&dense_model, &mut dense_state, 1, ctx.p.extra_steps, "dense")?;
    let moe_series = ctx.run_branch(&moe_model, &mut moe_state, 2, ctx.p.extra_steps, "upcycled")?;

    let get = |s: &sparse_upcycle::metrics::Series, k: &str| {
        s.last().and_then(|pt| pt.values.get(k).copied()).unwrap_or(f64::NAN)
    };
    println!("\n== results after +{} steps ==", ctx.p.extra_steps);
    println!(
        "  dense continuation: loss {:.4}  token-acc {:.4}",
        get(&dense_series, "loss"),
        get(&dense_series, "accuracy")
    );
    println!(
        "  upcycled MoE:       loss {:.4}  token-acc {:.4}",
        get(&moe_series, "loss"),
        get(&moe_series, "accuracy")
    );
    let win = get(&moe_series, "accuracy") - get(&dense_series, "accuracy");
    println!("  upcycling advantage: {win:+.4} token accuracy");
    Ok(())
}
